//! SIMD ↔ scalar bitwise parity: `--simd` must be a pure wall-clock
//! knob.
//!
//! The kernels layer pins a lane-striped reduction order (see
//! `kernels::simd`) that both the scalar references and the vector
//! bodies execute, so every dispatched kernel must produce **bitwise
//! identical** output under `SimdMode::Off` and `SimdMode::Auto` — at
//! every shape (vector main loop, scalar tail, and both), every KV
//! tier, and every thread count. This file sweeps the row primitives,
//! all six GEMM families, RMSNorm/softmax, the fused RoPE re-encode
//! paths, and the end-to-end coordinator stream.
//!
//! On a machine whose detected ISA is scalar, `Auto` and `Off` run the
//! same code and every assertion here is trivially true — the file
//! stays green everywhere while pinning real vector-vs-scalar parity
//! wherever AVX2/NEON is live.

use block_attn::config::KvPrecision;
use block_attn::coordinator::{AttentionMode, Coordinator, Request};
use block_attn::kernels::{
    active_isa, axpy, axpy_i4, axpy_i8, dot, dot_i4, dot_i8, gemm_nn_acc, gemm_nn_i4_acc,
    gemm_nn_i8_acc, gemm_nt_acc, gemm_nt_i4_acc, gemm_nt_i8_acc, gemm_tn_acc, isa_name, quant,
    rms_norm_rows, set_simd_mode, set_threads, softmax_inplace, Isa, SimdMode,
};
use block_attn::rope::RopeTable;
use block_attn::runtime::NativeBackend;
use block_attn::util::rng::Rng;
use block_attn::ModelConfig;
use std::sync::Mutex;

/// Every test here flips the process-global SIMD mode (and some flip
/// the thread budget); serialize so the harness cannot interleave the
/// two sides of a comparison.
static SIMD_LOCK: Mutex<()> = Mutex::new(());

/// Run `f` under `Off` then `Auto` and return both results. The caller
/// asserts equality; leaving the process in `Auto` afterwards matches
/// the default every other test expects.
fn under_both_modes<T>(mut f: impl FnMut() -> T) -> (T, T) {
    set_simd_mode(SimdMode::Off);
    let scalar = f();
    set_simd_mode(SimdMode::Auto);
    let simd = f();
    (scalar, simd)
}

fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal() as f32).collect()
}

/// Lengths that exercise the vector main loop (multiples of 8), the
/// scalar tail alone (< 8), and both together (odd > 8).
fn sweep_lens() -> Vec<usize> {
    let mut v: Vec<usize> = (0..40).collect();
    v.extend([64, 65, 127, 128, 130, 333]);
    v
}

#[test]
fn isa_dispatch_is_self_consistent() {
    let _g = SIMD_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    assert_eq!(set_simd_mode(SimdMode::Off), Isa::Scalar);
    assert_eq!(active_isa(), Isa::Scalar);
    assert_eq!(isa_name(), "scalar");
    let auto = set_simd_mode(SimdMode::Auto);
    assert_eq!(active_isa(), auto);
    assert_eq!(isa_name(), auto.name());
    #[cfg(target_arch = "x86_64")]
    assert_eq!(auto == Isa::Avx2, std::is_x86_feature_detected!("avx2"));
    #[cfg(target_arch = "aarch64")]
    assert_eq!(auto == Isa::Neon, std::arch::is_aarch64_feature_detected!("neon"));
}

#[test]
fn rowops_bitwise_parity_across_lengths() {
    let _g = SIMD_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let mut rng = Rng::new(0x51D0);
    for n in sweep_lens() {
        let a = randv(&mut rng, n);
        let b = randv(&mut rng, n);
        let y0 = randv(&mut rng, n);
        let alpha = rng.normal() as f32;
        let q: Vec<i8> = (0..n).map(|_| (rng.below(255) as i64 - 127) as i8).collect();
        let scale: Vec<f32> = (0..n).map(|_| (rng.normal() as f32).abs() * 0.02 + 1e-4).collect();

        let (s, v) = under_both_modes(|| dot(&a, &b));
        assert_eq!(s.to_bits(), v.to_bits(), "dot len={n}");
        let (s, v) = under_both_modes(|| dot_i8(&a, &q, &scale));
        assert_eq!(s.to_bits(), v.to_bits(), "dot_i8 len={n}");
        let (s, v) = under_both_modes(|| {
            let mut y = y0.clone();
            axpy(alpha, &a, &mut y);
            y
        });
        assert_eq!(s, v, "axpy len={n}");
        let (s, v) = under_both_modes(|| {
            let mut y = y0.clone();
            axpy_i8(alpha, &q, &scale, &mut y);
            y
        });
        assert_eq!(s, v, "axpy_i8 len={n}");

        if n % 2 == 0 {
            let packed: Vec<u8> = (0..n / 2).map(|_| rng.below(256) as u8).collect();
            let (s, v) = under_both_modes(|| dot_i4(&a, &packed, &scale));
            assert_eq!(s.to_bits(), v.to_bits(), "dot_i4 len={n}");
            let (s, v) = under_both_modes(|| {
                let mut y = y0.clone();
                axpy_i4(alpha, &packed, &scale, &mut y);
                y
            });
            assert_eq!(s, v, "axpy_i4 len={n}");
        }
    }
}

#[test]
fn norm_and_softmax_bitwise_parity() {
    let _g = SIMD_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let mut rng = Rng::new(0x50F7);
    // Odd row widths hit the f64 4-lane tail and the normalize tail.
    for (l, d) in [(1usize, 1usize), (3, 7), (2, 8), (5, 13), (4, 64), (3, 67)] {
        let x = randv(&mut rng, l * d);
        let w = randv(&mut rng, d);
        let (s, v) = under_both_modes(|| {
            let mut out = vec![0.0f32; l * d];
            let mut rstd = vec![0.0f32; l];
            rms_norm_rows(&x, &w, 1e-5, l, d, &mut out, &mut rstd);
            (out, rstd)
        });
        assert_eq!(s, v, "rms_norm_rows {l}x{d}");
    }
    for n in sweep_lens() {
        if n == 0 {
            continue;
        }
        let x = randv(&mut rng, n);
        let (s, v) = under_both_modes(|| {
            let mut row = x.clone();
            softmax_inplace(&mut row);
            row
        });
        assert_eq!(s, v, "softmax_inplace len={n}");
    }
}

/// Per-shared-dim-channel int8 quantization of a `rows×n` operand (the
/// canonical recipe from `kernels::quant`).
fn quant_cols(b: &[f32], rows: usize, n: usize) -> (Vec<i8>, Vec<f32>) {
    let scale = quant::channel_scales(b, rows, n);
    let q = b.iter().enumerate().map(|(i, &v)| quant::quantize_one(v, scale[i % n])).collect();
    (q, scale)
}

#[test]
fn gemm_families_bitwise_parity_on_odd_shapes() {
    let _g = SIMD_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let mut rng = Rng::new(0x6E33);
    // (m, k, n): below/above the micro-tile sizes, odd edges, and a
    // GEMV-shaped m=1 row (the decode path).
    for (m, k, n) in
        [(1usize, 8usize, 16usize), (3, 5, 7), (4, 16, 16), (5, 17, 19), (17, 34, 9), (1, 130, 33)]
    {
        let a = randv(&mut rng, m * k);
        let b_kn = randv(&mut rng, k * n);
        let b_nk = randv(&mut rng, n * k);
        let b_mn = randv(&mut rng, m * n);
        let seed = randv(&mut rng, m * n);

        let (s, v) = under_both_modes(|| {
            let mut out = seed.clone();
            gemm_nn_acc(&a, &b_kn, m, k, n, &mut out);
            out
        });
        assert_eq!(s, v, "gemm_nn_acc {m}x{k}x{n}");

        let (s, v) = under_both_modes(|| {
            let mut out = seed.clone();
            gemm_nt_acc(&a, &b_nk, m, k, n, &mut out);
            out
        });
        assert_eq!(s, v, "gemm_nt_acc {m}x{k}x{n}");

        let (s, v) = under_both_modes(|| {
            let mut out = vec![0.25f32; k * n];
            gemm_tn_acc(&a, &b_mn, m, k, n, &mut out);
            out
        });
        assert_eq!(s, v, "gemm_tn_acc {m}x{k}x{n}");

        // Quantized families: shared dim is k for nt (b is n×k), n for nn.
        let (bq_nt, bs_nt) = quant_cols(&b_nk, n, k);
        let (s, v) = under_both_modes(|| {
            let mut out = seed.clone();
            gemm_nt_i8_acc(&a, &bq_nt, &bs_nt, m, k, n, &mut out);
            out
        });
        assert_eq!(s, v, "gemm_nt_i8_acc {m}x{k}x{n}");

        let (bq_nn, bs_nn) = quant_cols(&b_kn, k, n);
        let (s, v) = under_both_modes(|| {
            let mut out = seed.clone();
            gemm_nn_i8_acc(&a, &bq_nn, &bs_nn, m, k, n, &mut out);
            out
        });
        assert_eq!(s, v, "gemm_nn_i8_acc {m}x{k}x{n}");

        if k % 2 == 0 {
            let (bq4, bs4) = quant::quantize_cols_i4(&b_nk, n, k);
            let (s, v) = under_both_modes(|| {
                let mut out = seed.clone();
                gemm_nt_i4_acc(&a, &bq4, &bs4, m, k, n, &mut out);
                out
            });
            assert_eq!(s, v, "gemm_nt_i4_acc {m}x{k}x{n}");
        }
        if n % 2 == 0 {
            let (bq4, bs4) = quant::quantize_cols_i4(&b_kn, k, n);
            let (s, v) = under_both_modes(|| {
                let mut out = seed.clone();
                gemm_nn_i4_acc(&a, &bq4, &bs4, m, k, n, &mut out);
                out
            });
            assert_eq!(s, v, "gemm_nn_i4_acc {m}x{k}x{n}");
        }
    }
}

#[test]
fn rope_reencode_paths_bitwise_parity() {
    let _g = SIMD_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    use block_attn::kernels::{QuantizedKv, QuantizedKv4};
    use block_attn::tensor::Tensor;
    // 37 tokens ⇒ a partial int4 scale group; head_dim 16 has a full
    // 8-lane rotation plus no tail, head_dim 12 an all-tail half of 6.
    for (layers, seq, heads, d) in [(2usize, 37usize, 2usize, 16usize), (1, 5, 3, 12)] {
        let table = RopeTable::new(d, 10000.0);
        let mut rng = Rng::new(0xA0E5);
        let raw = randv(&mut rng, layers * seq * heads * d);
        let x = Tensor::from_vec(&[layers, seq, heads, d], raw.clone());
        let kq8 = QuantizedKv::quantize(&x);
        let kq4 = QuantizedKv4::quantize(&x);
        for &delta in &[0i64, 1, 37, 4096] {
            let (s, v) = under_both_modes(|| {
                let mut k = raw.clone();
                table.reencode_block(&mut k, layers, seq, heads, delta);
                k
            });
            assert_eq!(s, v, "reencode_block d={d} delta={delta}");
            let (s, v) = under_both_modes(|| {
                let mut out = vec![0.0f32; raw.len()];
                table.reencode_block_dequant(
                    &kq8.q, &kq8.scales, layers, seq, heads, delta, &mut out,
                );
                out
            });
            assert_eq!(s, v, "reencode_block_dequant d={d} delta={delta}");
            let (s, v) = under_both_modes(|| {
                let mut out = vec![0.0f32; raw.len()];
                table.reencode_block_dequant_i4(
                    &kq4.packed, &kq4.scales, layers, seq, heads, delta, &mut out,
                );
                out
            });
            assert_eq!(s, v, "reencode_block_dequant_i4 d={d} delta={delta}");
            let (s, v) = under_both_modes(|| (kq8.dequantize(), kq4.dequantize()));
            assert_eq!(s.0.data(), v.0.data(), "QuantizedKv::dequantize");
            assert_eq!(s.1.data(), v.1.data(), "QuantizedKv4::dequantize");
        }
    }
}

// -- end to end ------------------------------------------------------

fn micro_config() -> ModelConfig {
    ModelConfig {
        name: "micro".into(),
        vocab: 24,
        d_model: 16,
        layers: 2,
        heads: 2,
        kv_heads: 1,
        head_dim: 8,
        d_ff: 32,
        rope_theta: 10000.0,
        norm_eps: 1e-5,
        max_len: 256,
    }
}

/// A request stream with shared blocks (cache hits), fresh blocks
/// (concurrent misses), and mixed attention modes — the same shape the
/// thread-determinism suite uses.
fn request_stream(vocab: usize) -> Vec<Request> {
    let mut rng = Rng::new(99);
    let mut block = |len: usize| -> Vec<i32> { (0..len).map(|_| rng.below(vocab) as i32).collect() };
    let shared_a = block(10);
    let shared_b = block(7);
    let dup = block(5);
    let mut reqs = Vec::new();
    for (i, mode) in [
        AttentionMode::Block,
        AttentionMode::Block,
        AttentionMode::BlockNoReencode,
        AttentionMode::Full,
    ]
    .iter()
    .enumerate()
    {
        let blocks = match i {
            0 => vec![shared_a.clone(), block(9), dup.clone(), dup.clone()],
            1 => vec![shared_a.clone(), shared_b.clone(), block(12)],
            _ => vec![shared_b.clone(), block(6)],
        };
        reqs.push(Request { id: i as u64, blocks, query: block(8), max_new_tokens: 6, mode: *mode });
    }
    reqs
}

/// Serve the stream on a fresh coordinator at the given budget, tier,
/// and SIMD mode; return everything deterministic about the responses.
fn serve(threads: usize, precision: KvPrecision, mode: SimdMode) -> Vec<(Vec<i32>, usize, usize)> {
    set_threads(threads);
    set_simd_mode(mode);
    let engine = NativeBackend::new(micro_config(), 0xD15C);
    let mut coord = Coordinator::with_kv_precision(engine, 64 << 20, precision);
    request_stream(24)
        .iter()
        .map(|req| {
            let resp = coord.process(req).expect("process");
            (resp.tokens.clone(), resp.cached_blocks, resp.prompt_tokens)
        })
        .collect()
}

/// The headline contract: `--simd auto` vs `--simd off` serve
/// byte-identical streams at every thread count × KV tier — prefill,
/// Eq.-3 re-encode, quantized decode attention and all.
#[test]
fn coordinator_stream_identical_across_simd_modes() {
    let _g = SIMD_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let prev = block_attn::kernels::num_threads();
    for precision in [KvPrecision::F32, KvPrecision::Int8, KvPrecision::Int4] {
        for threads in [1usize, 3, 8] {
            let off = serve(threads, precision, SimdMode::Off);
            let auto = serve(threads, precision, SimdMode::Auto);
            assert_eq!(
                off,
                auto,
                "serving stream differs between --simd off and auto ({} tier, {threads} threads, auto isa {})",
                precision.as_str(),
                isa_name()
            );
            assert!(off.iter().all(|(tokens, ..)| !tokens.is_empty()));
        }
    }
    set_threads(prev);
    set_simd_mode(SimdMode::Auto);
}
