//! TCP JSON-line serving front-end.
//!
//! Protocol: one JSON object per line.
//!
//! Request:
//! ```json
//! {"id": 1, "passages": ["doc a", "doc b"], "query": "what ...?",
//!  "max_new_tokens": 16, "mode": "block"}
//! ```
//! Response:
//! ```json
//! {"id": 1, "text": "...", "ttft_ms": 12.3, "flops_tft": 1.2e9,
//!  "cached_blocks": 2, "total_blocks": 2}
//! ```
//!
//! Architecture: the engine is `!Send`, so a dedicated **engine thread**
//! owns the [`Coordinator`] and serves jobs from an mpsc channel;
//! connection handlers (on the [`ThreadPool`]) parse requests, submit
//! jobs and stream responses back — the vLLM-router shape at miniature
//! scale. Python is nowhere in this path.

use crate::coordinator::{AttentionMode, Coordinator, Request, Response};
use crate::runtime::Backend;
use crate::tokenizer::ByteTokenizer;
use crate::util::json::Json;
use crate::util::pool::ThreadPool;
use anyhow::{anyhow, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc;

/// A parsed wire request.
pub fn parse_request(line: &str, tok: &ByteTokenizer) -> Result<Request> {
    let j = Json::parse(line).map_err(|e| anyhow!("bad json: {e}"))?;
    let id = j.get("id").as_usize().unwrap_or(0) as u64;
    let mode = AttentionMode::parse(j.get("mode").as_str().unwrap_or("block"))?;
    let passages = j
        .get("passages")
        .as_arr()
        .unwrap_or(&[])
        .iter()
        .filter_map(|p| p.as_str())
        .map(|p| {
            let mut ids = tok.encode(p);
            ids.push(crate::tokenizer::SEP);
            ids
        })
        .collect();
    let query_text = j.req_str("query")?;
    let mut query = vec![crate::tokenizer::QRY];
    query.extend(tok.encode(query_text));
    Ok(Request {
        id,
        blocks: passages,
        query,
        max_new_tokens: j.get("max_new_tokens").as_usize().unwrap_or(16),
        mode,
    })
}

/// Serialize a response line.
pub fn format_response(resp: &Response, tok: &ByteTokenizer) -> String {
    Json::obj(vec![
        ("id", Json::num(resp.id as f64)),
        ("text", Json::str(tok.decode_until_eos(&resp.tokens))),
        ("ttft_ms", Json::num(resp.ttft * 1e3)),
        ("block_prefill_ms", Json::num(resp.block_prefill_s * 1e3)),
        ("flops_tft", Json::num(resp.flops_tft)),
        ("cached_blocks", Json::num(resp.cached_blocks as f64)),
        ("total_blocks", Json::num(resp.total_blocks as f64)),
        ("prompt_tokens", Json::num(resp.prompt_tokens as f64)),
    ])
    .to_string()
}

fn format_error(id: u64, err: &str) -> String {
    Json::obj(vec![
        ("id", Json::num(id as f64)),
        ("error", Json::str(err)),
    ])
    .to_string()
}

enum Job {
    Generate(Request, mpsc::Sender<String>),
    Stats(mpsc::Sender<String>),
}

/// Handle to the engine thread.
#[derive(Clone)]
pub struct EngineHandle {
    tx: mpsc::Sender<Job>,
}

impl EngineHandle {
    /// Spawn the engine thread around a coordinator factory. The factory
    /// runs *on* the engine thread: backends need not be `Send` (the
    /// PJRT engine wraps raw C pointers), so the coordinator is built
    /// where it lives.
    pub fn spawn<B: Backend + 'static>(
        make: impl FnOnce() -> Result<Coordinator<B>> + Send + 'static,
    ) -> Result<EngineHandle> {
        let (tx, rx) = mpsc::channel::<Job>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        std::thread::Builder::new()
            .name("block-attn-engine".into())
            .spawn(move || {
                let tok = ByteTokenizer::new();
                let mut coord = match make() {
                    Ok(c) => {
                        let _ = ready_tx.send(Ok(()));
                        c
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(job) = rx.recv() {
                    match job {
                        Job::Generate(req, out) => {
                            let id = req.id;
                            let line = match coord.process(&req) {
                                Ok(resp) => format_response(&resp, &tok),
                                Err(e) => format_error(id, &format!("{e:#}")),
                            };
                            let _ = out.send(line);
                        }
                        Job::Stats(out) => {
                            let s = coord.cache_stats();
                            let ps = crate::kernels::pool_stats();
                            let m = &coord.metrics;
                            let line = Json::obj(vec![
                                ("metrics", Json::str(m.report())),
                                ("block_prefill_p50_ms", Json::num(m.block_prefill_p50_ms())),
                                ("cache_entries", Json::num(s.entries as f64)),
                                ("cache_bytes", Json::num(s.bytes as f64)),
                                ("cache_bytes_saved", Json::num(s.bytes_saved as f64)),
                                ("cache_bytes_saved_int8", Json::num(s.bytes_saved_int8 as f64)),
                                ("cache_bytes_saved_int4", Json::num(s.bytes_saved_int4 as f64)),
                                ("cache_hits", Json::num(s.hits as f64)),
                                ("cache_misses", Json::num(s.misses as f64)),
                                ("cache_evictions", Json::num(s.evictions as f64)),
                                ("cache_hit_rate", Json::num(s.hit_rate())),
                                ("cache_quant_rel_err", Json::num(s.quant_rel_err())),
                                ("kv_precision", Json::str(coord.kv_precision().as_str())),
                                ("threads", Json::num(crate::kernels::num_threads() as f64)),
                                ("pool_workers", Json::num(ps.workers as f64)),
                                ("pool_jobs_executed", Json::num(ps.jobs_executed as f64)),
                                ("pool_jobs_panicked", Json::num(ps.jobs_panicked as f64)),
                                ("pool_queue_peak", Json::num(ps.queue_peak as f64)),
                            ])
                            .to_string();
                            let _ = out.send(line);
                        }
                    }
                }
            })?;
        ready_rx.recv().map_err(|_| anyhow!("engine thread died"))??;
        Ok(EngineHandle { tx })
    }

    /// Synchronous generate (used by connection handlers and tests).
    pub fn generate(&self, req: Request) -> Result<String> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Job::Generate(req, tx))
            .map_err(|_| anyhow!("engine gone"))?;
        rx.recv().map_err(|_| anyhow!("engine gone"))
    }

    pub fn stats(&self) -> Result<String> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Job::Stats(tx))
            .map_err(|_| anyhow!("engine gone"))?;
        rx.recv().map_err(|_| anyhow!("engine gone"))
    }
}

/// Serve forever on `addr` (e.g. "127.0.0.1:7841").
pub fn serve(addr: &str, handle: EngineHandle, workers: usize) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    eprintln!("[server] listening on {addr}");
    let pool = ThreadPool::new(workers);
    for stream in listener.incoming() {
        let stream = stream?;
        let handle = handle.clone();
        pool.spawn(move || {
            if let Err(e) = handle_conn(stream, handle) {
                eprintln!("[server] connection error: {e:#}");
            }
        });
    }
    Ok(())
}

fn handle_conn(stream: TcpStream, handle: EngineHandle) -> Result<()> {
    let tok = ByteTokenizer::new();
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let out = if line.trim() == "stats" {
            handle.stats()?
        } else {
            match parse_request(&line, &tok) {
                Ok(req) => handle.generate(req)?,
                Err(e) => format_error(0, &format!("{e:#}")),
            }
        };
        writer.write_all(out.as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_request_roundtrip() {
        let tok = ByteTokenizer::new();
        let req = parse_request(
            r#"{"id": 3, "passages": ["doc a"], "query": "q?", "mode": "full", "max_new_tokens": 5}"#,
            &tok,
        )
        .unwrap();
        assert_eq!(req.id, 3);
        assert_eq!(req.blocks.len(), 1);
        assert_eq!(req.mode, AttentionMode::Full);
        assert_eq!(req.max_new_tokens, 5);
        assert_eq!(req.query[0], crate::tokenizer::QRY);
    }

    #[test]
    fn parse_rejects_missing_query() {
        let tok = ByteTokenizer::new();
        assert!(parse_request(r#"{"id": 1}"#, &tok).is_err());
        assert!(parse_request("not json", &tok).is_err());
    }

    #[test]
    fn response_is_valid_json() {
        let tok = ByteTokenizer::new();
        let resp = Response {
            id: 9,
            tokens: vec![b'h' as i32, b'i' as i32, crate::tokenizer::EOS],
            ttft: 0.0123,
            block_prefill_s: 0.0042,
            flops_tft: 1e9,
            cached_blocks: 2,
            total_blocks: 3,
            prompt_tokens: 100,
        };
        let line = format_response(&resp, &tok);
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.get("text").as_str(), Some("hi"));
        assert_eq!(j.get("cached_blocks").as_i64(), Some(2));
        assert!((j.get("ttft_ms").as_f64().unwrap() - 12.3).abs() < 0.01);
        assert!((j.get("block_prefill_ms").as_f64().unwrap() - 4.2).abs() < 0.01);
    }
}
