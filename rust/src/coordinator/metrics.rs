//! Serving metrics: TTFT, FLOPs-to-first-token, cache efficiency,
//! throughput. These are the quantities of the paper's Table 3 and §3.6.

use crate::util::stats::Summary;

/// Aggregated serving metrics.
pub struct Metrics {
    pub ttft: Summary,
    /// Wall time of the concurrent cache-miss block prefill, recorded
    /// only for requests that actually computed misses (the part
    /// `--threads` parallelizes; all-hit requests don't contribute).
    pub block_prefill: Summary,
    pub flops_tft: Summary,
    pub decode_lens: Summary,
    pub requests: u64,
    pub blocks_seen: u64,
    pub blocks_cached: u64,
    /// Decode rounds issued by the continuous-batching loop (one round
    /// = one `decode_batch` dispatch advancing every active session).
    pub decode_rounds: u64,
    /// Tokens decoded by those rounds (sum of per-round batch sizes).
    pub decode_tokens: u64,
    started: std::time::Instant,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            ttft: Summary::new(),
            block_prefill: Summary::new(),
            flops_tft: Summary::new(),
            decode_lens: Summary::new(),
            requests: 0,
            blocks_seen: 0,
            blocks_cached: 0,
            decode_rounds: 0,
            decode_tokens: 0,
            started: std::time::Instant::now(),
        }
    }

    /// One continuous-batching decode round advanced `batched` sessions.
    pub fn record_decode_round(&mut self, batched: usize) {
        self.decode_rounds += 1;
        self.decode_tokens += batched as u64;
    }

    /// Mean sessions advanced per decode round — the batching win is
    /// this number approaching `BatchPolicy::max_active` under load
    /// (1.0 means the loop degenerated to serial decoding).
    pub fn batch_occupancy(&self) -> f64 {
        if self.decode_rounds == 0 {
            0.0
        } else {
            self.decode_tokens as f64 / self.decode_rounds as f64
        }
    }

    pub fn record_ttft(&mut self, seconds: f64, flops: f64) {
        self.ttft.add(seconds);
        self.flops_tft.add(flops);
        self.requests += 1;
    }

    pub fn record_block_prefill(&mut self, seconds: f64) {
        self.block_prefill.add(seconds);
    }

    /// Median concurrent-miss-prefill time in ms; 0.0 before the first
    /// miss-bearing request. Must stay finite — the empty-reservoir
    /// quantile is NaN, which is not representable in the stats JSON
    /// this feeds.
    pub fn block_prefill_p50_ms(&self) -> f64 {
        if self.block_prefill.count() == 0 {
            0.0
        } else {
            self.block_prefill.p50() * 1e3
        }
    }

    pub fn record_cache(&mut self, cached: usize, total: usize) {
        self.blocks_cached += cached as u64;
        self.blocks_seen += total as u64;
    }

    pub fn record_completion(&mut self, generated: usize) {
        self.decode_lens.add(generated as f64);
    }

    pub fn block_hit_rate(&self) -> f64 {
        if self.blocks_seen == 0 {
            0.0
        } else {
            self.blocks_cached as f64 / self.blocks_seen as f64
        }
    }

    /// Requests per wall-clock second since creation.
    pub fn throughput_rps(&self) -> f64 {
        let dt = self.started.elapsed().as_secs_f64();
        if dt <= 0.0 {
            0.0
        } else {
            self.requests as f64 / dt
        }
    }

    pub fn report(&self) -> String {
        format!(
            "requests={} ttft_p50={:.1}ms ttft_p95={:.1}ms block_prefill_p50={:.1}ms \
             flops_tft_mean={:.3e} block_hit_rate={:.1}% throughput={:.2} req/s \
             decode_rounds={} batch_occupancy={:.2}",
            self.requests,
            self.ttft.p50() * 1e3,
            self.ttft.p95() * 1e3,
            self.block_prefill_p50_ms(),
            self.flops_tft.mean(),
            self.block_hit_rate() * 100.0,
            self.throughput_rps(),
            self.decode_rounds,
            self.batch_occupancy(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accounting() {
        let mut m = Metrics::new();
        assert_eq!(m.block_prefill_p50_ms(), 0.0, "empty summary must stay finite");
        m.record_ttft(0.010, 1e9);
        m.record_ttft(0.020, 2e9);
        m.record_block_prefill(0.004);
        assert!((m.block_prefill_p50_ms() - 4.0).abs() < 1e-9);
        m.record_cache(3, 4);
        m.record_cache(1, 4);
        m.record_completion(7);
        assert_eq!(m.batch_occupancy(), 0.0, "no rounds yet");
        m.record_decode_round(3);
        m.record_decode_round(1);
        assert_eq!(m.decode_rounds, 2);
        assert_eq!(m.decode_tokens, 4);
        assert!((m.batch_occupancy() - 2.0).abs() < 1e-12);
        assert!(m.report().contains("decode_rounds=2"));
        assert_eq!(m.requests, 2);
        assert!((m.block_hit_rate() - 0.5).abs() < 1e-12);
        assert!((m.flops_tft.mean() - 1.5e9).abs() < 1.0);
        assert!(m.ttft.p50() >= 0.010);
        assert!(m.report().contains("requests=2"));
    }
}
