//! Analytic FLOPs model of the transformer — produces the FLOPs-TFT rows
//! of the paper's Table 3.
//!
//! Counting convention (matches the standard 2·MAC accounting the paper
//! uses): a matmul of (m×k)·(k×n) costs 2mkn FLOPs. Attention costs the
//! QK^T and PV contractions against the number of *attended* keys, which
//! is where Block-attention wins: a cached block costs zero prefill
//! FLOPs and only the final block pays attention over the context.

use crate::config::ModelConfig;

/// Per-component FLOPs for one model config.
#[derive(Debug, Clone)]
pub struct FlopsModel {
    d_model: usize,
    layers: usize,
    heads: usize,
    kv_heads: usize,
    head_dim: usize,
    d_ff: usize,
    vocab: usize,
}

impl FlopsModel {
    pub fn from_config(cfg: &ModelConfig) -> FlopsModel {
        FlopsModel {
            d_model: cfg.d_model,
            layers: cfg.layers,
            heads: cfg.heads,
            kv_heads: cfg.kv_heads,
            head_dim: cfg.head_dim,
            d_ff: cfg.d_ff,
            vocab: cfg.vocab,
        }
    }

    /// Linear-projection FLOPs for `n` tokens in one layer
    /// (QKV + output + SwiGLU MLP).
    fn layer_linear(&self, n: f64) -> f64 {
        let d = self.d_model as f64;
        let hq = (self.heads * self.head_dim) as f64;
        let hkv = (self.kv_heads * self.head_dim) as f64;
        let f = self.d_ff as f64;
        // wq, wk, wv, wo
        let attn_proj = 2.0 * n * d * hq + 2.0 * 2.0 * n * d * hkv + 2.0 * n * hq * d;
        // gate, up, down
        let mlp = 3.0 * 2.0 * n * d * f;
        attn_proj + mlp
    }

    /// Attention-contraction FLOPs for `nq` queries each attending `nk`
    /// keys in one layer (QK^T + PV over all q heads).
    fn layer_attention(&self, nq: f64, nk: f64) -> f64 {
        let hd = self.head_dim as f64;
        let h = self.heads as f64;
        2.0 * 2.0 * h * nq * nk * hd
    }

    /// LM-head projection for the single next-token logit row.
    fn lm_head(&self) -> f64 {
        2.0 * (self.d_model * self.vocab) as f64
    }

    /// FLOPs to first token of a vanilla full prefill of `n` tokens.
    /// Causal attention: token i attends i+1 keys → ~n²/2 pairs.
    pub fn prefill_full(&self, n: usize) -> f64 {
        let nf = n as f64;
        let per_layer = self.layer_linear(nf) + self.layer_attention(nf, (nf + 1.0) / 2.0);
        self.layers as f64 * per_layer + self.lm_head()
    }

    /// FLOPs of the final-block prefill: `q` query tokens attending the
    /// full `ctx + q` context (context keys + causal self).
    pub fn prefill_final(&self, q: usize, ctx: usize) -> f64 {
        let qf = q as f64;
        let per_layer = self.layer_linear(qf)
            + self.layer_attention(qf, ctx as f64 + (qf + 1.0) / 2.0);
        self.layers as f64 * per_layer + self.lm_head()
    }

    /// FLOPs of re-encoding a cached block of `n` tokens (paper Eq. 3):
    /// 6 FLOPs per (layer, token, kv-head, pair) — negligible by design,
    /// but counted for honesty.
    pub fn reencode(&self, n: usize) -> f64 {
        (self.layers * n * self.kv_heads * self.head_dim * 3) as f64
    }

    /// FLOPs of one decode step at context length `ctx`.
    pub fn decode_step(&self, ctx: usize) -> f64 {
        let per_layer = self.layer_linear(1.0) + self.layer_attention(1.0, ctx as f64 + 1.0);
        self.layers as f64 * per_layer + self.lm_head()
    }

    /// Block-mode FLOPs-TFT with everything cached except the final
    /// block: re-encode + final prefill (the paper's Table-3 block row).
    pub fn block_mode_tft(&self, q: usize, ctx: usize) -> f64 {
        self.reencode(ctx) + self.prefill_final(q, ctx)
    }

    // -- paper-convention accounting ----------------------------------------
    //
    // Table 3 of the paper counts *weight* FLOPs only (2·params·tokens):
    // its vanilla row scales exactly linearly in total length and its
    // block row is flat at the user-input cost, and the reported
    // reductions match `1 - q/n` (90.1% at 512, 99.8% at 32K). We
    // reproduce that convention here and additionally report the exact
    // count (attention contractions included) from the methods above.

    /// Weight-only FLOPs for prefilling `n` tokens (paper convention).
    pub fn weights_prefill(&self, n: usize) -> f64 {
        self.layers as f64 * self.layer_linear(n as f64) + self.lm_head()
    }

    /// Weight-only block-mode FLOPs-TFT: only the final `q` tokens are
    /// computed, regardless of context length (paper convention).
    pub fn weights_block_tft(&self, q: usize) -> f64 {
        self.layers as f64 * self.layer_linear(q as f64) + self.lm_head()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            vocab: 32000,
            d_model: 256,
            layers: 4,
            heads: 8,
            kv_heads: 4,
            head_dim: 32,
            d_ff: 688,
            rope_theta: 10000.0,
            norm_eps: 1e-5,
            max_len: 32768,
        }
    }

    #[test]
    fn full_prefill_superlinear_block_flat() {
        let f = FlopsModel::from_config(&cfg());
        let full_1k = f.prefill_full(1024);
        let full_8k = f.prefill_full(8192);
        // Superlinear growth (linear terms + quadratic attention).
        assert!(full_8k > 8.0 * full_1k);

        // Exact block-mode FLOPs still grow (the final block's attention
        // over the context is linear in ctx) but remain a tiny fraction
        // of vanilla: >95% reduction at 8K even with exact accounting.
        let blk_8k = f.block_mode_tft(50, 8192);
        let red = 1.0 - blk_8k / full_8k;
        assert!(red > 0.95, "reduction {red}");
    }

    #[test]
    fn paper_convention_reductions_match_table3() {
        // Paper Table 3 (weight-FLOPs convention): 90.1% reduction at
        // total length 512, 99.8% at 32K, block row flat.
        let f = FlopsModel::from_config(&cfg());
        let q = 50;
        let red512 = 1.0 - f.weights_block_tft(q) / f.weights_prefill(512);
        let red32k = 1.0 - f.weights_block_tft(q) / f.weights_prefill(32768);
        assert!((red512 - 0.901).abs() < 0.02, "512: {red512}");
        assert!((red32k - 0.998).abs() < 0.005, "32K: {red32k}");
        assert_eq!(f.weights_block_tft(q), f.weights_block_tft(q));
    }

    #[test]
    fn hand_check_linear_terms() {
        let f = FlopsModel::from_config(&cfg());
        // One token, one layer linear: wq 2*d*hq + wk/wv 2*2*d*hkv + wo
        // 2*hq*d + mlp 6*d*f.
        let d = 256.0;
        let expect = 2.0 * d * 256.0 + 4.0 * d * 128.0 + 2.0 * 256.0 * d + 6.0 * d * 688.0;
        assert!((f.layer_linear(1.0) - expect).abs() < 1.0);
    }

    #[test]
    fn decode_flops_grow_with_context() {
        let f = FlopsModel::from_config(&cfg());
        assert!(f.decode_step(8192) > f.decode_step(512));
    }
}
