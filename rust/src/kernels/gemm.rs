//! Cache-blocked (tiled) GEMM kernels with deterministic accumulation.
//!
//! Three layouts cover every contraction in the native forward and
//! backward passes:
//!
//! * [`gemm_nn_acc`] — `out[m×n] += a[m×k] @ b[k×n]` (projections)
//! * [`gemm_nt_acc`] — `out[m×p] += a[m×n] @ b[p×n]ᵀ` (logits, dX)
//! * [`gemm_tn_acc`] — `out[k×n] += a[m×k]ᵀ @ b[m×n]` (dW)
//!
//! The tiling is a register-blocked micro-kernel (`MR×NR` accumulator
//! tile held in locals, loaded from / stored back to `out`) under a
//! row-parallel outer loop ([`par_rows`]). Two invariants make the
//! kernels drop-in replacements for the scalar loops they replace:
//!
//! 1. **Reduction order.** Every output element accumulates its
//!    contributions in a fixed floating-point sequence seeded from
//!    `out`: the `nn`/`tn` families in ascending reduction index into
//!    a single f32 accumulator, the `nt` families (row-row dot
//!    products, f32/int8/int4 alike) in the **lane-striped** order of
//!    [`super::rowops::dot`] (8 fixed partial sums folded ascending —
//!    see [`super::simd`]). Tiling, edge fallbacks, the parallel split,
//!    and the `--simd` setting all preserve those exact sequences, so
//!    results are bitwise identical across tile boundaries, thread
//!    counts, and ISAs.
//! 2. **Row independence.** An output row is a function of its input
//!    row only, so computing rows `0..l` of a longer product yields the
//!    same prefix — the property the block-serving equivalence tests
//!    rely on.
//!
//! SIMD: the serial `nn`/`tn` tiles dispatch on
//! [`super::simd::active_isa`] to AVX2 register-tiled twins (mul+add,
//! per-element order unchanged — see `simd::x86`), and the `nt`
//! families inherit vector dispatch through the
//! [`super::rowops::dot`]/[`dot_i8`](super::rowops::dot_i8)/
//! [`dot_i4`](super::rowops::dot_i4) primitives they are built from.
//! The scalar tiles below remain the always-available reference: the
//! auto-vectorizer still sees independent accumulator lanes, and every
//! vector twin is gated on bitwise parity with them.

use super::parallel::par_rows;

/// Rows per register tile.
const MR: usize = 4;
/// Columns per register tile (two AVX lanes worth of f32).
const NR: usize = 16;

/// Below this `m·k·n` volume a GEMM is not worth dispatching to the
/// worker pool. Pool dispatch (queue push + condvar wake) is ~two
/// orders cheaper than the per-region thread spawn it replaced, so the
/// floor sits well below the old spawn-amortization point.
const PAR_MIN_VOLUME: usize = 1 << 19;

/// Minimum per-chunk volume when splitting rows across workers.
const CHUNK_MIN_VOLUME: usize = 1 << 16;

fn min_rows_for(vol_per_row: usize) -> usize {
    (CHUNK_MIN_VOLUME / vol_per_row.max(1)).max(MR)
}

// -- nn: out[m×n] += a[m×k] @ b[k×n] ---------------------------------------

/// `out[m×n] += a[m×k] @ b[k×n]`.
pub fn gemm_nn_acc(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    if m * k * n >= PAR_MIN_VOLUME {
        par_rows(out, n, min_rows_for(k * n), |r0, chunk| {
            let rows = chunk.len() / n;
            nn_serial(&a[r0 * k..(r0 + rows) * k], b, rows, k, n, chunk);
        });
    } else {
        nn_serial(a, b, m, k, n, out);
    }
}

/// `out[m×n] = a[m×k] @ b[k×n]`.
pub fn gemm_nn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    out.fill(0.0);
    gemm_nn_acc(a, b, m, k, n, out);
}

fn nn_serial(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    #[cfg(target_arch = "x86_64")]
    if super::simd::active_isa() == super::simd::Isa::Avx2 {
        // SAFETY: `Isa::Avx2` is only stored after runtime detection.
        unsafe { super::simd::x86::nn_serial_avx2(a, b, m, k, n, out) };
        return;
    }
    nn_serial_scalar(a, b, m, k, n, out);
}

fn nn_serial_scalar(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    let mut i = 0;
    while i + MR <= m {
        let mut j = 0;
        while j + NR <= n {
            nn_micro(a, b, i, j, k, n, out);
            j += NR;
        }
        if j < n {
            for r in 0..MR {
                let arow = &a[(i + r) * k..(i + r + 1) * k];
                nn_row_edge(arow, b, k, n, j, &mut out[(i + r) * n..(i + r + 1) * n]);
            }
        }
        i += MR;
    }
    for r in i..m {
        nn_row_edge(&a[r * k..(r + 1) * k], b, k, n, 0, &mut out[r * n..(r + 1) * n]);
    }
}

/// One `MR×NR` register tile: load, accumulate over all of `k`
/// (ascending), store.
#[inline]
fn nn_micro(a: &[f32], b: &[f32], i0: usize, j0: usize, k: usize, n: usize, out: &mut [f32]) {
    let mut acc = [[0.0f32; NR]; MR];
    for (r, row) in acc.iter_mut().enumerate() {
        let o = (i0 + r) * n + j0;
        row.copy_from_slice(&out[o..o + NR]);
    }
    for p in 0..k {
        let brow = &b[p * n + j0..p * n + j0 + NR];
        for (r, row) in acc.iter_mut().enumerate() {
            let av = a[(i0 + r) * k + p];
            for (c, &bv) in brow.iter().enumerate() {
                row[c] += av * bv;
            }
        }
    }
    for (r, row) in acc.iter().enumerate() {
        let o = (i0 + r) * n + j0;
        out[o..o + NR].copy_from_slice(row);
    }
}

/// Column tail of one row: same ascending-k in-place accumulation the
/// scalar saxpy loop performs (bitwise identical to the micro-kernel).
#[inline]
fn nn_row_edge(arow: &[f32], b: &[f32], k: usize, n: usize, j0: usize, orow: &mut [f32]) {
    for (p, &av) in arow.iter().enumerate().take(k) {
        let brow = &b[p * n + j0..(p + 1) * n];
        for (o, &bv) in orow[j0..].iter_mut().zip(brow) {
            *o += av * bv;
        }
    }
}

// -- nt: out[m×p] += a[m×n] @ b[p×n]ᵀ --------------------------------------

/// `out[m×p] += a[m×n] @ b[p×n]ᵀ` (both operands row-major; each output
/// element is a row-row dot product).
pub fn gemm_nt_acc(a: &[f32], b: &[f32], m: usize, n: usize, p: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * n);
    debug_assert_eq!(b.len(), p * n);
    debug_assert_eq!(out.len(), m * p);
    if m * n * p >= PAR_MIN_VOLUME {
        par_rows(out, p, min_rows_for(n * p), |r0, chunk| {
            let rows = chunk.len() / p;
            nt_serial(&a[r0 * n..(r0 + rows) * n], b, rows, n, p, chunk);
        });
    } else {
        nt_serial(a, b, m, n, p, out);
    }
}

/// Every output element is one striped row-row dot product, seeded
/// from `out` with a single add of the folded result. Built directly
/// on [`super::rowops::dot`], so the `nt` family dispatches to the
/// vector ISAs through one primitive, the decode-path `dot` callers
/// stay bitwise aligned with the batched GEMM, and there is no
/// tile/edge split to keep in sync — `m=1` GEMVs and wide batches run
/// the identical per-element sequence.
fn nt_serial(a: &[f32], b: &[f32], m: usize, n: usize, p: usize, out: &mut [f32]) {
    for i in 0..m {
        let arow = &a[i * n..(i + 1) * n];
        for (j, o) in out[i * p..(i + 1) * p].iter_mut().enumerate() {
            *o += super::rowops::dot(arow, &b[j * n..(j + 1) * n]);
        }
    }
}

// -- tn: out[k×n] += a[m×k]ᵀ @ b[m×n] --------------------------------------

/// `out[k×n] += a[m×k]ᵀ @ b[m×n]` (the weight-gradient contraction; the
/// reduction runs over `m`, ascending).
pub fn gemm_tn_acc(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), m * n);
    debug_assert_eq!(out.len(), k * n);
    if m * k * n >= PAR_MIN_VOLUME {
        par_rows(out, n, min_rows_for(m * n), |r0, chunk| {
            let rows = chunk.len() / n;
            tn_serial(a, b, m, k, n, r0, rows, chunk);
        });
    } else {
        tn_serial(a, b, m, k, n, 0, k, out);
    }
}

/// Serial tn over output rows `[p0, p0+rows)`; `out` holds only those
/// rows.
#[allow(clippy::too_many_arguments)]
fn tn_serial(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    p0: usize,
    rows: usize,
    out: &mut [f32],
) {
    #[cfg(target_arch = "x86_64")]
    if super::simd::active_isa() == super::simd::Isa::Avx2 {
        // SAFETY: `Isa::Avx2` is only stored after runtime detection.
        unsafe { super::simd::x86::tn_serial_avx2(a, b, m, k, n, p0, rows, out) };
        return;
    }
    tn_serial_scalar(a, b, m, k, n, p0, rows, out);
}

#[allow(clippy::too_many_arguments)]
fn tn_serial_scalar(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    p0: usize,
    rows: usize,
    out: &mut [f32],
) {
    let mut r = 0;
    while r + MR <= rows {
        let mut j = 0;
        while j + NR <= n {
            tn_micro(a, b, m, k, n, p0 + r, r, j, out);
            j += NR;
        }
        if j < n {
            for rr in r..r + MR {
                tn_row_edge(a, b, m, k, n, p0 + rr, j, &mut out[rr * n..(rr + 1) * n]);
            }
        }
        r += MR;
    }
    for rr in r..rows {
        tn_row_edge(a, b, m, k, n, p0 + rr, 0, &mut out[rr * n..(rr + 1) * n]);
    }
}

/// Tile over output rows `p0g..p0g+MR` (global) at local row `rl`,
/// columns `j0..j0+NR`; reduction over `m` ascending.
#[inline]
#[allow(clippy::too_many_arguments)]
fn tn_micro(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    p0g: usize,
    rl: usize,
    j0: usize,
    out: &mut [f32],
) {
    let mut acc = [[0.0f32; NR]; MR];
    for (r, row) in acc.iter_mut().enumerate() {
        let o = (rl + r) * n + j0;
        row.copy_from_slice(&out[o..o + NR]);
    }
    for i in 0..m {
        let brow = &b[i * n + j0..i * n + j0 + NR];
        for (r, row) in acc.iter_mut().enumerate() {
            let av = a[i * k + p0g + r];
            for (c, &bv) in brow.iter().enumerate() {
                row[c] += av * bv;
            }
        }
    }
    for (r, row) in acc.iter().enumerate() {
        let o = (rl + r) * n + j0;
        out[o..o + NR].copy_from_slice(row);
    }
}

#[inline]
#[allow(clippy::too_many_arguments)]
fn tn_row_edge(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    pg: usize,
    j0: usize,
    orow: &mut [f32],
) {
    for i in 0..m {
        let av = a[i * k + pg];
        let brow = &b[i * n + j0..(i + 1) * n];
        for (o, &bv) in orow[j0..].iter_mut().zip(brow) {
            *o += av * bv;
        }
    }
}

// -- mixed precision: int8 operand with per-channel f32 scales -------------

/// `out[m×p] += a[m×n] @ (b_q[p×n] ⊙ scale[n])ᵀ` — the QKᵀ contraction
/// with an int8-quantized K operand. `scale` has one entry per shared
/// (channel) index `n`; dequantization `q·s` is fused into the inner
/// loop, per-element and order-free, so the reduction order (the
/// lane-striped [`super::rowops::dot`] order, seeded from `out`) is
/// identical to running [`gemm_nt_acc`] over a pre-dequantized
/// operand — bitwise.
pub fn gemm_nt_i8_acc(
    a: &[f32],
    b_q: &[i8],
    b_scale: &[f32],
    m: usize,
    n: usize,
    p: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(a.len(), m * n);
    debug_assert_eq!(b_q.len(), p * n);
    debug_assert_eq!(b_scale.len(), n);
    debug_assert_eq!(out.len(), m * p);
    if m * n * p >= PAR_MIN_VOLUME {
        par_rows(out, p, min_rows_for(n * p), |r0, chunk| {
            let rows = chunk.len() / p;
            let a_rows = &a[r0 * n..(r0 + rows) * n];
            nt_i8_serial(a_rows, b_q, b_scale, rows, n, p, chunk);
        });
    } else {
        nt_i8_serial(a, b_q, b_scale, m, n, p, out);
    }
}

fn nt_i8_serial(
    a: &[f32],
    b_q: &[i8],
    b_scale: &[f32],
    m: usize,
    n: usize,
    p: usize,
    out: &mut [f32],
) {
    for i in 0..m {
        let arow = &a[i * n..(i + 1) * n];
        for (j, o) in out[i * p..(i + 1) * p].iter_mut().enumerate() {
            *o += super::rowops::dot_i8(arow, &b_q[j * n..(j + 1) * n], b_scale);
        }
    }
}

/// `out[m×n] += a[m×k] @ (b_q[k×n] ⊙ scale[n])` — the AV contraction
/// with an int8-quantized V operand (`scale` is per output channel).
/// Same fused per-element dequant and ascending-`k` in-place
/// accumulation as the f32 saxpy loop it mirrors.
pub fn gemm_nn_i8_acc(
    a: &[f32],
    b_q: &[i8],
    b_scale: &[f32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b_q.len(), k * n);
    debug_assert_eq!(b_scale.len(), n);
    debug_assert_eq!(out.len(), m * n);
    if m * k * n >= PAR_MIN_VOLUME {
        par_rows(out, n, min_rows_for(k * n), |r0, chunk| {
            let rows = chunk.len() / n;
            let a_rows = &a[r0 * k..(r0 + rows) * k];
            nn_i8_serial(a_rows, b_q, b_scale, rows, k, n, chunk);
        });
    } else {
        nn_i8_serial(a, b_q, b_scale, m, k, n, out);
    }
}

fn nn_i8_serial(
    a: &[f32],
    b_q: &[i8],
    b_scale: &[f32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
) {
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (pp, &av) in arow.iter().enumerate() {
            super::rowops::axpy_i8(av, &b_q[pp * n..(pp + 1) * n], b_scale, orow);
        }
    }
}

// -- mixed precision: packed int4 operand, per-channel f32 scales ----------

/// `out[m×p] += a[m×n] @ (unpack(b_q4[p×n]) ⊙ scale[n])ᵀ` — the QKᵀ
/// contraction with a packed-int4 K operand (two codes per byte along
/// the shared axis `n`, which must be even; `scale` has one entry per
/// shared index). Unpack + dequant are per-element and order-free, so
/// the reduction order matches [`gemm_nt_acc`] over a pre-dequantized
/// operand — bitwise.
pub fn gemm_nt_i4_acc(
    a: &[f32],
    b_q4: &[u8],
    b_scale: &[f32],
    m: usize,
    n: usize,
    p: usize,
    out: &mut [f32],
) {
    assert!(n % 2 == 0, "int4 GEMM needs an even shared dim, got {n}");
    debug_assert_eq!(a.len(), m * n);
    debug_assert_eq!(b_q4.len(), p * n / 2);
    debug_assert_eq!(b_scale.len(), n);
    debug_assert_eq!(out.len(), m * p);
    if m * n * p >= PAR_MIN_VOLUME {
        par_rows(out, p, min_rows_for(n * p), |r0, chunk| {
            let rows = chunk.len() / p;
            let a_rows = &a[r0 * n..(r0 + rows) * n];
            nt_i4_serial(a_rows, b_q4, b_scale, rows, n, p, chunk);
        });
    } else {
        nt_i4_serial(a, b_q4, b_scale, m, n, p, out);
    }
}

fn nt_i4_serial(
    a: &[f32],
    b_q4: &[u8],
    b_scale: &[f32],
    m: usize,
    n: usize,
    p: usize,
    out: &mut [f32],
) {
    let half = n / 2;
    for i in 0..m {
        let arow = &a[i * n..(i + 1) * n];
        for (j, o) in out[i * p..(i + 1) * p].iter_mut().enumerate() {
            // Striped dot seeded from `out` (each byte contributes its
            // even then odd channel) — the exact sequence of running
            // `rowops::dot` over the dequantized row.
            *o += super::rowops::dot_i4(arow, &b_q4[j * half..(j + 1) * half], b_scale);
        }
    }
}

/// `out[m×n] += a[m×k] @ (unpack(b_q4[k×n]) ⊙ scale[n])` — the AV
/// contraction with a packed-int4 V operand (`n` even, two codes per
/// byte along it; `scale` per output channel). Same fused per-element
/// dequant and ascending-`k` in-place accumulation as the f32 saxpy
/// loop it mirrors.
pub fn gemm_nn_i4_acc(
    a: &[f32],
    b_q4: &[u8],
    b_scale: &[f32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
) {
    assert!(n % 2 == 0, "int4 GEMM needs an even packed dim, got {n}");
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b_q4.len(), k * n / 2);
    debug_assert_eq!(b_scale.len(), n);
    debug_assert_eq!(out.len(), m * n);
    if m * k * n >= PAR_MIN_VOLUME {
        par_rows(out, n, min_rows_for(k * n), |r0, chunk| {
            let rows = chunk.len() / n;
            let a_rows = &a[r0 * k..(r0 + rows) * k];
            nn_i4_serial(a_rows, b_q4, b_scale, rows, k, n, chunk);
        });
    } else {
        nn_i4_serial(a, b_q4, b_scale, m, k, n, out);
    }
}

fn nn_i4_serial(
    a: &[f32],
    b_q4: &[u8],
    b_scale: &[f32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
) {
    let half = n / 2;
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (pp, &av) in arow.iter().enumerate() {
            super::rowops::axpy_i4(av, &b_q4[pp * half..(pp + 1) * half], b_scale, orow);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::set_threads;
    use crate::util::rng::Rng;

    fn randvec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    /// Reference with the kernels' reduction order: per element, seed
    /// from `out`, accumulate ascending reduction index.
    fn ref_nn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
        for i in 0..m {
            for j in 0..n {
                let mut acc = out[i * n + j];
                for p in 0..k {
                    acc += a[i * k + p] * b[p * n + j];
                }
                out[i * n + j] = acc;
            }
        }
    }

    /// Independent formulation of the nt contract: per element, one
    /// lane-striped dot (`i % 8` lanes folded ascending — the
    /// `kernels::simd` order) added to the seed from `out`.
    fn ref_nt(a: &[f32], b: &[f32], m: usize, n: usize, p: usize, out: &mut [f32]) {
        fn striped_dot(a: &[f32], b: &[f32]) -> f32 {
            let mut lanes = [0.0f32; 8];
            for (i, (x, y)) in a.iter().zip(b).enumerate() {
                lanes[i % 8] += x * y;
            }
            let mut s = lanes[0];
            for &l in &lanes[1..] {
                s += l;
            }
            s
        }
        for i in 0..m {
            for j in 0..p {
                out[i * p + j] += striped_dot(&a[i * n..(i + 1) * n], &b[j * n..(j + 1) * n]);
            }
        }
    }

    fn ref_tn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
        for p in 0..k {
            for j in 0..n {
                let mut acc = out[p * n + j];
                for i in 0..m {
                    acc += a[i * k + p] * b[i * n + j];
                }
                out[p * n + j] = acc;
            }
        }
    }

    /// Odd shapes exercise every tile-edge path.
    const SHAPES: &[(usize, usize, usize)] = &[
        (1, 1, 1),
        (3, 5, 7),
        (4, 16, 16),
        (5, 17, 19),
        (17, 33, 9),
        (33, 8, 65),
        (64, 64, 64),
    ];

    #[test]
    fn nn_matches_reference_bitwise() {
        let mut rng = Rng::new(11);
        for &(m, k, n) in SHAPES {
            let a = randvec(&mut rng, m * k);
            let b = randvec(&mut rng, k * n);
            let seed = randvec(&mut rng, m * n);
            let mut want = seed.clone();
            ref_nn(&a, &b, m, k, n, &mut want);
            let mut got = seed.clone();
            gemm_nn_acc(&a, &b, m, k, n, &mut got);
            assert_eq!(got, want, "nn mismatch at {m}x{k}x{n}");
        }
    }

    #[test]
    fn nt_matches_reference_bitwise() {
        let mut rng = Rng::new(12);
        for &(m, n, p) in SHAPES {
            let a = randvec(&mut rng, m * n);
            let b = randvec(&mut rng, p * n);
            let seed = randvec(&mut rng, m * p);
            let mut want = seed.clone();
            ref_nt(&a, &b, m, n, p, &mut want);
            let mut got = seed.clone();
            gemm_nt_acc(&a, &b, m, n, p, &mut got);
            assert_eq!(got, want, "nt mismatch at {m}x{n}x{p}");
        }
    }

    #[test]
    fn tn_matches_reference_bitwise() {
        let mut rng = Rng::new(13);
        for &(m, k, n) in SHAPES {
            let a = randvec(&mut rng, m * k);
            let b = randvec(&mut rng, m * n);
            let seed = randvec(&mut rng, k * n);
            let mut want = seed.clone();
            ref_tn(&a, &b, m, k, n, &mut want);
            let mut got = seed.clone();
            gemm_tn_acc(&a, &b, m, k, n, &mut got);
            assert_eq!(got, want, "tn mismatch at {m}x{k}x{n}");
        }
    }

    /// Quantize per shared-dim channel with the canonical scale formula.
    fn quant_cols(b: &[f32], rows: usize, n: usize) -> (Vec<i8>, Vec<f32>) {
        let scale = crate::kernels::quant::channel_scales(b, rows, n);
        let q = b
            .iter()
            .enumerate()
            .map(|(i, &v)| crate::kernels::quant::quantize_one(v, scale[i % n]))
            .collect();
        (q, scale)
    }

    #[test]
    fn int8_gemms_match_dequantized_f32_bitwise() {
        // The fused dequant must be invisible: int8 kernels == f32
        // kernels over the pre-dequantized operand, bit for bit.
        let mut rng = Rng::new(21);
        for &(m, k, n) in SHAPES {
            let a = randvec(&mut rng, m * k);
            let b = randvec(&mut rng, k * n);
            let seed = randvec(&mut rng, m * n);
            // nn layout: b is k×n, scales per column n.
            let (bq, bs) = quant_cols(&b, k, n);
            let deq: Vec<f32> = bq
                .iter()
                .enumerate()
                .map(|(i, &q)| q as f32 * bs[i % n])
                .collect();
            let mut want = seed.clone();
            gemm_nn_acc(&a, &deq, m, k, n, &mut want);
            let mut got = seed.clone();
            gemm_nn_i8_acc(&a, &bq, &bs, m, k, n, &mut got);
            assert_eq!(got, want, "nn_i8 mismatch at {m}x{k}x{n}");
            // nt layout: a is m×k, b is n×k (shared dim k), scales per k.
            let bt = randvec(&mut rng, n * k);
            let (btq, bts) = quant_cols(&bt, n, k);
            let deqt: Vec<f32> = btq
                .iter()
                .enumerate()
                .map(|(i, &q)| q as f32 * bts[i % k])
                .collect();
            let seed2 = randvec(&mut rng, m * n);
            let mut want2 = seed2.clone();
            ref_nt(&a, &deqt, m, k, n, &mut want2);
            let mut got2 = seed2.clone();
            gemm_nt_i8_acc(&a, &btq, &bts, m, k, n, &mut got2);
            assert_eq!(got2, want2, "nt_i8 mismatch at {m}x{k}x{n}");
        }
    }

    /// The shipped 2-D int4 operand recipe (canonical owner in
    /// `kernels::quant`).
    fn quant_cols_i4(b: &[f32], rows: usize, n: usize) -> (Vec<u8>, Vec<f32>) {
        crate::kernels::quant::quantize_cols_i4(b, rows, n)
    }

    /// Unpack + dequantize a packed operand back to f32 (test oracle;
    /// canonical owner in `kernels::quant`).
    fn dequant_cols_i4(packed: &[u8], scale: &[f32], n: usize) -> Vec<f32> {
        crate::kernels::quant::dequantize_cols_i4(packed, scale, n)
    }

    #[test]
    fn int4_gemms_match_dequantized_f32_bitwise() {
        // The fused unpack+dequant must be invisible: int4 kernels ==
        // f32 kernels over the pre-dequantized operand, bit for bit.
        // Even shared/packed dims only (nibble pairing).
        let mut rng = Rng::new(31);
        for &(m, k, n) in &[(1usize, 2usize, 2usize), (3, 6, 8), (5, 18, 20), (17, 34, 10)] {
            let a = randvec(&mut rng, m * k);
            let b = randvec(&mut rng, k * n);
            let seed = randvec(&mut rng, m * n);
            // nn layout: b is k×n packed along n, scales per column n.
            let (bq, bs) = quant_cols_i4(&b, k, n);
            let deq = dequant_cols_i4(&bq, &bs, n);
            let mut want = seed.clone();
            gemm_nn_acc(&a, &deq, m, k, n, &mut want);
            let mut got = seed.clone();
            gemm_nn_i4_acc(&a, &bq, &bs, m, k, n, &mut got);
            assert_eq!(got, want, "nn_i4 mismatch at {m}x{k}x{n}");
            // nt layout: a is m×k, b is n×k (shared dim k), scales per k.
            let bt = randvec(&mut rng, n * k);
            let (btq, bts) = quant_cols_i4(&bt, n, k);
            let deqt = dequant_cols_i4(&btq, &bts, k);
            let seed2 = randvec(&mut rng, m * n);
            let mut want2 = seed2.clone();
            ref_nt(&a, &deqt, m, k, n, &mut want2);
            let mut got2 = seed2.clone();
            gemm_nt_i4_acc(&a, &btq, &bts, m, k, n, &mut got2);
            assert_eq!(got2, want2, "nt_i4 mismatch at {m}x{k}x{n}");
        }
    }

    #[test]
    fn int4_gemm_parallel_split_is_bitwise_identical() {
        let _g = crate::kernels::TEST_THREADS_LOCK.lock().unwrap();
        let prev = crate::kernels::num_threads();
        let (m, k, n) = (128, 96, 128);
        let mut rng = Rng::new(32);
        let a = randvec(&mut rng, m * k);
        let b = randvec(&mut rng, k * n);
        let (bq, bs) = quant_cols_i4(&b, k, n);
        set_threads(1);
        let mut serial = vec![0.0f32; m * n];
        gemm_nn_i4_acc(&a, &bq, &bs, m, k, n, &mut serial);
        set_threads(8);
        let mut parallel = vec![0.0f32; m * n];
        gemm_nn_i4_acc(&a, &bq, &bs, m, k, n, &mut parallel);
        let bt = randvec(&mut rng, n * k);
        let (btq, bts) = quant_cols_i4(&bt, n, k);
        set_threads(1);
        let mut nt_s = vec![0.0f32; m * n];
        gemm_nt_i4_acc(&a, &btq, &bts, m, k, n, &mut nt_s);
        set_threads(8);
        let mut nt_p = vec![0.0f32; m * n];
        gemm_nt_i4_acc(&a, &btq, &bts, m, k, n, &mut nt_p);
        set_threads(prev);
        assert_eq!(serial, parallel, "nn_i4 differs across thread counts");
        assert_eq!(nt_s, nt_p, "nt_i4 differs across thread counts");
    }

    #[test]
    fn int8_gemm_parallel_split_is_bitwise_identical() {
        let _g = crate::kernels::TEST_THREADS_LOCK.lock().unwrap();
        let prev = crate::kernels::num_threads();
        let (m, k, n) = (128, 96, 128);
        let mut rng = Rng::new(22);
        let a = randvec(&mut rng, m * k);
        let b = randvec(&mut rng, k * n);
        let (bq, bs) = quant_cols(&b, k, n);
        set_threads(1);
        let mut serial = vec![0.0f32; m * n];
        gemm_nn_i8_acc(&a, &bq, &bs, m, k, n, &mut serial);
        set_threads(8);
        let mut parallel = vec![0.0f32; m * n];
        gemm_nn_i8_acc(&a, &bq, &bs, m, k, n, &mut parallel);
        let bt = randvec(&mut rng, n * k);
        let (btq, bts) = quant_cols(&bt, n, k);
        set_threads(1);
        let mut nt_s = vec![0.0f32; m * n];
        gemm_nt_i8_acc(&a, &btq, &bts, m, k, n, &mut nt_s);
        set_threads(8);
        let mut nt_p = vec![0.0f32; m * n];
        gemm_nt_i8_acc(&a, &btq, &bts, m, k, n, &mut nt_p);
        set_threads(prev);
        assert_eq!(serial, parallel, "nn_i8 differs across thread counts");
        assert_eq!(nt_s, nt_p, "nt_i8 differs across thread counts");
    }

    #[test]
    fn parallel_split_is_bitwise_identical() {
        let _g = crate::kernels::TEST_THREADS_LOCK.lock().unwrap();
        let prev = crate::kernels::num_threads();
        // Big enough to cross PAR_MIN_VOLUME so the row split engages.
        let (m, k, n) = (128, 96, 128);
        let mut rng = Rng::new(14);
        let a = randvec(&mut rng, m * k);
        let b = randvec(&mut rng, k * n);
        let mut serial = vec![0.0f32; m * n];
        set_threads(1);
        gemm_nn_acc(&a, &b, m, k, n, &mut serial);
        let mut parallel = vec![0.0f32; m * n];
        set_threads(8);
        gemm_nn_acc(&a, &b, m, k, n, &mut parallel);
        set_threads(1);
        let mut tn_s = vec![0.0f32; k * n];
        gemm_tn_acc(&a, &b, m, k, n, &mut tn_s);
        set_threads(8);
        let mut tn_p = vec![0.0f32; k * n];
        gemm_tn_acc(&a, &b, m, k, n, &mut tn_p);
        assert_eq!(serial, parallel, "nn differs across thread counts");
        assert_eq!(tn_s, tn_p, "tn differs across thread counts");
        set_threads(prev);
    }

    #[test]
    fn prefix_rows_match_longer_product() {
        // Row independence: the first rows of a taller GEMM equal the
        // short GEMM bitwise (the block-serving invariant).
        let (k, n) = (24, 40);
        let mut rng = Rng::new(15);
        let a = randvec(&mut rng, 20 * k);
        let b = randvec(&mut rng, k * n);
        let mut tall = vec![0.0f32; 20 * n];
        gemm_nn_acc(&a, &b, 20, k, n, &mut tall);
        let mut short = vec![0.0f32; 7 * n];
        gemm_nn_acc(&a[..7 * k], &b, 7, k, n, &mut short);
        assert_eq!(&tall[..7 * n], &short[..]);
    }
}
