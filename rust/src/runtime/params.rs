//! Flat-f32 checkpoint files, shared by every backend.
//!
//! A checkpoint is the concatenation of all parameter tensors as
//! little-endian f32 in [`ParamSpec`] order — the same layout
//! `python/compile/aot.py` writes for `init_file`, so checkpoints are
//! interchangeable between the native and artifact backends (both use
//! the manifest parameter order).

use crate::config::ParamSpec;
use crate::tensor::{Tensor, TensorF};
use anyhow::{bail, Context, Result};

/// Read a flat little-endian f32 checkpoint into the given layout.
pub fn read_flat_params(path: &std::path::Path, specs: &[ParamSpec]) -> Result<Vec<TensorF>> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
    let total: usize = specs.iter().map(|s| s.len()).sum();
    if bytes.len() != total * 4 {
        bail!(
            "checkpoint {path:?} has {} bytes, expected {} ({} f32)",
            bytes.len(),
            total * 4,
            total
        );
    }
    let mut floats = Vec::with_capacity(total);
    for c in bytes.chunks_exact(4) {
        floats.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
    }
    let mut out = Vec::with_capacity(specs.len());
    let mut off = 0;
    for s in specs {
        let n = s.len();
        out.push(Tensor::from_vec(&s.shape, floats[off..off + n].to_vec()));
        off += n;
    }
    Ok(out)
}

/// Write tensors as a flat little-endian f32 checkpoint.
pub fn write_flat_params(path: &std::path::Path, tensors: &[TensorF]) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut bytes = Vec::with_capacity(tensors.iter().map(|t| t.len() * 4).sum());
    for t in tensors {
        for x in t.data() {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
    }
    std::fs::write(path, bytes)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flat_params_roundtrip() {
        let dir = std::env::temp_dir().join("block_attn_test_ckpt");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("p.bin");
        let t1 = Tensor::from_vec(&[2, 3], vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let t2 = Tensor::from_vec(&[2], vec![-1.0f32, 0.5]);
        write_flat_params(&path, &[t1.clone(), t2.clone()]).unwrap();
        let specs = vec![
            ParamSpec { name: "a".into(), shape: vec![2, 3] },
            ParamSpec { name: "b".into(), shape: vec![2] },
        ];
        let back = read_flat_params(&path, &specs).unwrap();
        assert_eq!(back[0], t1);
        assert_eq!(back[1], t2);
        // Wrong layout must fail loudly.
        let bad = vec![ParamSpec { name: "a".into(), shape: vec![9] }];
        assert!(read_flat_params(&path, &bad).is_err());
    }
}
