//! Host-side tensors.
//!
//! Minimal row-major tensors used on the L3 side: KV blocks in the cache,
//! model parameters during training, and conversion to/from PJRT literals
//! (conversion lives in [`crate::runtime`] to keep this module
//! dependency-free and easy to test).

use std::fmt;

/// Row-major host tensor of `T`.
#[derive(Clone, PartialEq)]
pub struct Tensor<T> {
    dims: Vec<usize>,
    data: Vec<T>,
}

pub type TensorF = Tensor<f32>;
pub type TensorI = Tensor<i32>;

impl<T: Copy + Default> Tensor<T> {
    /// Zero-filled tensor.
    pub fn zeros(dims: &[usize]) -> Self {
        let n = dims.iter().product();
        Tensor { dims: dims.to_vec(), data: vec![T::default(); n] }
    }

    /// Build from parts; panics if the element count mismatches.
    pub fn from_vec(dims: &[usize], data: Vec<T>) -> Self {
        assert_eq!(
            dims.iter().product::<usize>(),
            data.len(),
            "shape {:?} does not match data length {}",
            dims,
            data.len()
        );
        Tensor { dims: dims.to_vec(), data }
    }

    pub fn scalar(v: T) -> Self {
        Tensor { dims: vec![], data: vec![v] }
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    pub fn rank(&self) -> usize {
        self.dims.len()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[T] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [T] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<T> {
        self.data
    }

    /// Reinterpret with a new shape of the same element count.
    pub fn reshape(mut self, dims: &[usize]) -> Self {
        assert_eq!(dims.iter().product::<usize>(), self.data.len());
        self.dims = dims.to_vec();
        self
    }

    /// Linear index of a multi-index.
    pub fn offset(&self, idx: &[usize]) -> usize {
        debug_assert_eq!(idx.len(), self.dims.len());
        let mut off = 0;
        for (i, (&x, &d)) in idx.iter().zip(&self.dims).enumerate() {
            debug_assert!(x < d, "index {idx:?} out of bounds {:?} at {i}", self.dims);
            off = off * d + x;
        }
        off
    }

    pub fn at(&self, idx: &[usize]) -> T {
        self.data[self.offset(idx)]
    }

    pub fn set(&mut self, idx: &[usize], v: T) {
        let off = self.offset(idx);
        self.data[off] = v;
    }

    /// Slice of the first axis: `self[i]` as a view (contiguous).
    pub fn axis0(&self, i: usize) -> &[T] {
        let stride: usize = self.dims[1..].iter().product();
        &self.data[i * stride..(i + 1) * stride]
    }

    pub fn axis0_mut(&mut self, i: usize) -> &mut [T] {
        let stride: usize = self.dims[1..].iter().product();
        &mut self.data[i * stride..(i + 1) * stride]
    }

    /// Copy `src` into the first-axis range `[at, at+src.dims[0])`.
    /// Remaining dims must match.
    pub fn write_axis0(&mut self, at: usize, src: &Tensor<T>) {
        assert_eq!(&self.dims[1..], &src.dims[1..], "trailing dims mismatch");
        let stride: usize = self.dims[1..].iter().product();
        let n = src.dims[0];
        assert!(at + n <= self.dims[0], "write_axis0 out of range");
        self.data[at * stride..(at + n) * stride].copy_from_slice(&src.data);
    }

    /// Extract first-axis range `[at, at+n)` as a new tensor.
    pub fn slice_axis0(&self, at: usize, n: usize) -> Tensor<T> {
        assert!(at + n <= self.dims[0]);
        let stride: usize = self.dims[1..].iter().product();
        let mut dims = self.dims.clone();
        dims[0] = n;
        Tensor { dims, data: self.data[at * stride..(at + n) * stride].to_vec() }
    }
}

impl Tensor<f32> {
    /// Max |a-b| between two equal-shaped tensors.
    pub fn max_abs_diff(&self, other: &Tensor<f32>) -> f32 {
        assert_eq!(self.dims, other.dims);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    pub fn size_bytes(&self) -> usize {
        self.data.len() * 4
    }
}

impl<T: fmt::Debug> fmt::Debug for Tensor<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}(n={})", self.dims, self.data.len())
    }
}

/// Argmax over a slice (greedy decode helper).
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    let mut bv = f32::NEG_INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        if x > bv {
            bv = x;
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indexing_row_major() {
        let mut t = Tensor::<f32>::zeros(&[2, 3, 4]);
        t.set(&[1, 2, 3], 7.0);
        assert_eq!(t.at(&[1, 2, 3]), 7.0);
        assert_eq!(t.offset(&[1, 2, 3]), 1 * 12 + 2 * 4 + 3);
        assert_eq!(t.data()[23], 7.0);
    }

    #[test]
    fn axis0_views() {
        let t = Tensor::from_vec(&[2, 3], vec![1, 2, 3, 4, 5, 6]);
        assert_eq!(t.axis0(0), &[1, 2, 3]);
        assert_eq!(t.axis0(1), &[4, 5, 6]);
    }

    #[test]
    fn write_and_slice_axis0() {
        let mut t = Tensor::<i32>::zeros(&[4, 2]);
        let src = Tensor::from_vec(&[2, 2], vec![1, 2, 3, 4]);
        t.write_axis0(1, &src);
        assert_eq!(t.data(), &[0, 0, 1, 2, 3, 4, 0, 0]);
        let s = t.slice_axis0(1, 2);
        assert_eq!(s.data(), &[1, 2, 3, 4]);
        assert_eq!(s.dims(), &[2, 2]);
    }

    #[test]
    #[should_panic(expected = "shape")]
    fn from_vec_checks_len() {
        let _ = Tensor::from_vec(&[2, 2], vec![1]);
    }

    #[test]
    fn argmax_picks_largest() {
        assert_eq!(argmax(&[0.1, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[-5.0, -2.0, -3.0]), 1);
    }

    #[test]
    fn max_abs_diff() {
        let a = Tensor::from_vec(&[3], vec![1.0f32, 2.0, 3.0]);
        let b = Tensor::from_vec(&[3], vec![1.5f32, 2.0, 2.0]);
        assert_eq!(a.max_abs_diff(&b), 1.0);
    }
}
