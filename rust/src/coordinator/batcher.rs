//! Continuous batching.
//!
//! vLLM-style scheduling adapted to this runtime: requests are admitted
//! FIFO under a slot + token budget; each admitted request runs its
//! prefill (TTFT is charged from the request's own arrival time), then
//! all active requests advance one token per decode round through a
//! single [`BatchExec::do_decode_batch`] dispatch. At most **one**
//! prefill is admitted per decode round, so ongoing decodes never stall
//! behind an admission burst. When a request finishes its slot is
//! refilled on the next round — prefills interleave with ongoing
//! decodes exactly as in continuous batching.
//!
//! The scheduler core is [`BatchRunner`]: the closed-set driver
//! ([`run_batch`] / [`run_batch_arrivals`], used by benches and tests)
//! and the live server engine loop (`server::EngineHandle`) are both
//! thin loops over [`BatchRunner::admit`] + [`BatchRunner::decode_round`].
//! Progress is reported through [`BatchEvent`]s so the server can stream
//! per-token frames while a bench just collects final responses.
//!
//! The batcher is generic over a [`BatchExec`] so its scheduling
//! invariants are property-tested with a mock executor, independent of
//! the inference engine.

use super::{Coordinator, DecodeState, Request, Response};
use crate::runtime::Backend;
use crate::tokenizer::EOS;
use crate::util::cli::Args;
use anyhow::{anyhow, bail, ensure, Result};
use std::collections::VecDeque;
use std::time::Instant;

/// Execution interface the batcher drives.
pub trait BatchExec {
    type State;
    /// Run prefill; returns decode state + the response skeleton holding
    /// the first token and final TTFT/FLOPs numbers.
    fn do_prefill(&mut self, req: &Request, t0: Instant) -> Result<(Self::State, Response)>;
    /// Advance one decode step.
    fn do_decode(&mut self, state: &mut Self::State, last: i32) -> Result<i32>;
    /// Advance every in-flight session one token. The default decodes
    /// serially; engines with a batched hot path override this (see
    /// `Backend::decode_batch` — bitwise identical to the serial path).
    fn do_decode_batch(
        &mut self,
        states: &mut [&mut Self::State],
        last: &[i32],
    ) -> Result<Vec<i32>> {
        states
            .iter_mut()
            .zip(last)
            .map(|(s, &l)| self.do_decode(s, l))
            .collect()
    }
    /// Observer: one decode round advanced `batched` sessions.
    fn on_decode_round(&mut self, batched: usize) {
        let _ = batched;
    }
    /// Observer: a request retired with its final response.
    fn on_complete(&mut self, resp: &Response) {
        let _ = resp;
    }
}

impl<B: Backend> BatchExec for Coordinator<B> {
    type State = DecodeState;

    fn do_prefill(&mut self, req: &Request, t0: Instant) -> Result<(DecodeState, Response)> {
        self.prefill(req, t0)
    }

    fn do_decode(&mut self, state: &mut DecodeState, last: i32) -> Result<i32> {
        self.decode_one(state, last)
    }

    fn do_decode_batch(
        &mut self,
        states: &mut [&mut DecodeState],
        last: &[i32],
    ) -> Result<Vec<i32>> {
        self.decode_batch(states, last)
    }

    fn on_decode_round(&mut self, batched: usize) {
        self.metrics.record_decode_round(batched);
    }

    fn on_complete(&mut self, resp: &Response) {
        self.metrics.record_completion(resp.tokens.len());
    }
}

/// Batching policy knobs.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    /// Max concurrently-decoding requests.
    pub max_active: usize,
    /// Max summed prompt tokens across active requests (backpressure).
    pub max_active_tokens: usize,
    /// Bound of the server's admission queue — requests parked between
    /// `submit` and admission. A full queue blocks `submit` (client
    /// backpressure) instead of growing without bound.
    pub queue_depth: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_active: 4, max_active_tokens: 16 * 1024, queue_depth: 64 }
    }
}

impl BatchPolicy {
    /// Policy from `$BLOCK_ATTN_MAX_ACTIVE`, `$BLOCK_ATTN_MAX_ACTIVE_TOKENS`
    /// and `$BLOCK_ATTN_QUEUE_DEPTH` (unset/empty → defaults). Panics on
    /// unparsable values: a misconfigured deployment should fail loudly
    /// at startup, not silently serve with default batching.
    pub fn from_env() -> BatchPolicy {
        let d = BatchPolicy::default();
        BatchPolicy {
            max_active: env_usize("BLOCK_ATTN_MAX_ACTIVE", d.max_active),
            max_active_tokens: env_usize("BLOCK_ATTN_MAX_ACTIVE_TOKENS", d.max_active_tokens),
            queue_depth: env_usize("BLOCK_ATTN_QUEUE_DEPTH", d.queue_depth),
        }
    }

    /// Resolution order (mirrors `KvPrecision::resolve`): explicit flag
    /// (`--max-active`, `--max-active-tokens`, `--queue-depth`) beats the
    /// environment, which beats the built-in default.
    pub fn resolve(args: &Args) -> BatchPolicy {
        let env = BatchPolicy::from_env();
        BatchPolicy {
            max_active: args.usize_or("max-active", env.max_active).max(1),
            max_active_tokens: args
                .usize_or("max-active-tokens", env.max_active_tokens)
                .max(1),
            queue_depth: args.usize_or("queue-depth", env.queue_depth).max(1),
        }
    }
}

fn env_usize(name: &str, default: usize) -> usize {
    match parse_env_usize(std::env::var(name).ok().as_deref()) {
        Ok(n) => n.unwrap_or(default),
        Err(e) => panic!("invalid ${name}: {e}"),
    }
}

/// The pure parsing behind [`BatchPolicy::from_env`] (testable without
/// mutating the process environment). Unset/empty → `None`.
pub(crate) fn parse_env_usize(v: Option<&str>) -> Result<Option<usize>> {
    match v {
        Some(s) if !s.trim().is_empty() => {
            let n: usize = s
                .trim()
                .parse()
                .map_err(|_| anyhow!("expected a positive integer, got {s:?}"))?;
            ensure!(n > 0, "expected a positive integer, got {s:?}");
            Ok(Some(n))
        }
        _ => Ok(None),
    }
}

/// A request parked in the admission queue: its arrival time (TTFT is
/// charged from here, not from some shared batch start) plus a caller
/// tag threaded through the events it generates (the server uses the
/// per-connection reply channel as the tag).
pub struct Pending<T> {
    pub req: Request,
    pub arrived: Instant,
    pub tag: T,
}

/// Scheduling events emitted by [`BatchRunner`]. `Token` fires for
/// every generated token (including the prefill's first); `Done`
/// retires a request with its final [`Response`]; `Failed` reports a
/// per-request prefill error or an engine-level decode error.
pub enum BatchEvent<T> {
    Token { tag: T, id: u64, token: i32 },
    Done { tag: T, resp: Response },
    Failed { tag: T, id: u64, error: String },
}

struct Active<S, T> {
    req: Request,
    state: S,
    resp: Response,
    tag: T,
}

/// The continuous-batching scheduler core: the active set plus the
/// admission budgets of a [`BatchPolicy`]. Drive it by alternating
/// [`Self::admit`] (at most once per round, guarded by
/// [`Self::can_admit`]) with [`Self::decode_round`].
///
/// Invariant kept by `admit`/`decode_round`: every active entry has a
/// non-EOS last token and room for more tokens — finished requests
/// retire (and free their slot) the moment their last token lands.
pub struct BatchRunner<S, T> {
    policy: BatchPolicy,
    active: Vec<Active<S, T>>,
}

impl<S, T: Clone> BatchRunner<S, T> {
    pub fn new(policy: BatchPolicy) -> BatchRunner<S, T> {
        BatchRunner { policy, active: Vec::new() }
    }

    pub fn policy(&self) -> &BatchPolicy {
        &self.policy
    }

    pub fn has_active(&self) -> bool {
        !self.active.is_empty()
    }

    pub fn active_len(&self) -> usize {
        self.active.len()
    }

    /// Summed prompt tokens of the active set (the token-budget meter).
    pub fn active_tokens(&self) -> usize {
        self.active.iter().map(|a| a.req.prompt_tokens()).sum()
    }

    /// Would `req` fit right now? The first request always fits — a
    /// prompt larger than the whole token budget must run solo rather
    /// than deadlock the queue.
    pub fn can_admit(&self, req: &Request) -> bool {
        self.active.is_empty()
            || (self.active.len() < self.policy.max_active
                && self.active_tokens() + req.prompt_tokens() <= self.policy.max_active_tokens)
    }

    /// Admit one request: run its prefill (TTFT measured from
    /// `p.arrived`) and either retire it immediately (EOS or token
    /// limit hit on the first token) or add it to the active set. The
    /// caller checks [`Self::can_admit`] first.
    pub fn admit<E: BatchExec<State = S>>(
        &mut self,
        exec: &mut E,
        p: Pending<T>,
        mut sink: impl FnMut(BatchEvent<T>),
    ) {
        let Pending { req, arrived, tag } = p;
        let (state, resp) = match exec.do_prefill(&req, arrived) {
            Ok(out) => out,
            Err(e) => {
                sink(BatchEvent::Failed { tag, id: req.id, error: format!("{e:#}") });
                return;
            }
        };
        let first = *resp.tokens.last().expect("prefill must emit a first token");
        sink(BatchEvent::Token { tag: tag.clone(), id: resp.id, token: first });
        if first == EOS || resp.tokens.len() >= req.max_new_tokens {
            exec.on_complete(&resp);
            sink(BatchEvent::Done { tag, resp });
        } else {
            self.active.push(Active { req, state, resp, tag });
        }
    }

    /// One decode round: advance every active session one token through
    /// a single [`BatchExec::do_decode_batch`] dispatch, emit `Token`
    /// events, retire finished sessions. A decode error is engine-level
    /// (the whole batch shares one dispatch), so it fails every active
    /// request and empties the runner.
    pub fn decode_round<E: BatchExec<State = S>>(
        &mut self,
        exec: &mut E,
        mut sink: impl FnMut(BatchEvent<T>),
    ) {
        if self.active.is_empty() {
            return;
        }
        exec.on_decode_round(self.active.len());
        let last: Vec<i32> = self.active.iter().map(|a| *a.resp.tokens.last().unwrap()).collect();
        let mut states: Vec<&mut S> = self.active.iter_mut().map(|a| &mut a.state).collect();
        let next = exec.do_decode_batch(&mut states, &last);
        drop(states);
        let next = match next {
            Ok(next) => next,
            Err(e) => {
                let msg = format!("{e:#}");
                for a in self.active.drain(..) {
                    sink(BatchEvent::Failed { tag: a.tag, id: a.resp.id, error: msg.clone() });
                }
                return;
            }
        };
        debug_assert_eq!(next.len(), self.active.len());
        for (a, &t) in self.active.iter_mut().zip(&next) {
            a.resp.tokens.push(t);
            sink(BatchEvent::Token { tag: a.tag.clone(), id: a.resp.id, token: t });
        }
        // Retire finished requests (their slots free immediately).
        let mut i = 0;
        while i < self.active.len() {
            let finished = {
                let a = &self.active[i];
                *a.resp.tokens.last().unwrap() == EOS
                    || a.resp.tokens.len() >= a.req.max_new_tokens
            };
            if finished {
                let a = self.active.remove(i);
                exec.on_complete(&a.resp);
                sink(BatchEvent::Done { tag: a.tag, resp: a.resp });
            } else {
                i += 1;
            }
        }
    }
}

/// Run a closed set of requests to completion with continuous batching.
/// All requests are treated as arriving now; responses are returned in
/// completion order.
pub fn run_batch<E: BatchExec>(
    exec: &mut E,
    requests: Vec<Request>,
    policy: &BatchPolicy,
) -> Result<Vec<Response>> {
    let now = Instant::now();
    run_batch_arrivals(exec, requests.into_iter().map(|r| (r, now)).collect(), policy)
}

/// [`run_batch`] with explicit per-request arrival times: each
/// response's TTFT covers queueing from *its own* arrival, not from a
/// shared batch start. The first error aborts the whole batch.
pub fn run_batch_arrivals<E: BatchExec>(
    exec: &mut E,
    requests: Vec<(Request, Instant)>,
    policy: &BatchPolicy,
) -> Result<Vec<Response>> {
    let mut queue: VecDeque<Pending<()>> = requests
        .into_iter()
        .map(|(req, arrived)| Pending { req, arrived, tag: () })
        .collect();
    let mut runner: BatchRunner<E::State, ()> = BatchRunner::new(policy.clone());
    let mut done: Vec<Response> = Vec::new();
    let mut failed: Option<String> = None;

    while !queue.is_empty() || runner.has_active() {
        {
            let mut sink = |ev: BatchEvent<()>| match ev {
                BatchEvent::Done { resp, .. } => done.push(resp),
                BatchEvent::Failed { error, .. } => {
                    failed.get_or_insert(error);
                }
                BatchEvent::Token { .. } => {}
            };
            // One admission per round, then everyone decodes: ongoing
            // sessions never stall behind an admission burst.
            if queue.front().map(|p| runner.can_admit(&p.req)).unwrap_or(false) {
                let p = queue.pop_front().unwrap();
                runner.admit(exec, p, &mut sink);
            }
            runner.decode_round(exec, &mut sink);
        }
        if let Some(e) = failed.take() {
            bail!("{e}");
        }
    }
    Ok(done)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::AttentionMode;
    use crate::util::prop;
    use crate::util::rng::Rng;
    use crate::{prop_assert, prop_assert_eq};
    use std::time::Duration;

    /// Scheduling-trace entry recorded by the mock executor.
    #[derive(Debug, Clone, PartialEq, Eq)]
    enum Op {
        Prefill { id: u64, needs_decode: bool },
        Round(usize),
    }

    /// Mock executor: generates `id`-derived tokens, records order and
    /// the admit/decode interleaving.
    #[derive(Default)]
    struct Mock {
        prefill_order: Vec<u64>,
        decode_calls: usize,
        ops: Vec<Op>,
    }

    impl BatchExec for Mock {
        type State = u64;

        fn do_prefill(&mut self, req: &Request, t0: Instant) -> Result<(u64, Response)> {
            self.prefill_order.push(req.id);
            // The mock's first token is never EOS, so a request decodes
            // iff it is allowed more than one token.
            self.ops.push(Op::Prefill { id: req.id, needs_decode: req.max_new_tokens > 1 });
            Ok((
                req.id,
                Response {
                    id: req.id,
                    tokens: vec![1],
                    ttft: t0.elapsed().as_secs_f64(),
                    block_prefill_s: 0.0,
                    flops_tft: 0.0,
                    cached_blocks: 0,
                    total_blocks: req.blocks.len(),
                    prompt_tokens: req.prompt_tokens(),
                },
            ))
        }

        fn do_decode(&mut self, state: &mut u64, last: i32) -> Result<i32> {
            self.decode_calls += 1;
            // Request `id` emits EOS after id%5 + 1 decode steps.
            let _ = last;
            *state += 1 << 32;
            let steps = (*state >> 32) as i32;
            if steps > (*state as u32 % 5) as i32 {
                Ok(EOS)
            } else {
                Ok(2)
            }
        }

        fn on_decode_round(&mut self, batched: usize) {
            self.ops.push(Op::Round(batched));
        }
    }

    fn req(id: u64, ntoks: usize, max_new: usize) -> Request {
        Request {
            id,
            blocks: vec![vec![0; ntoks]],
            query: vec![1, 2],
            max_new_tokens: max_new,
            mode: AttentionMode::Block,
        }
    }

    #[test]
    fn all_requests_complete_in_fifo_prefill_order() {
        let mut mock = Mock::default();
        let reqs: Vec<Request> = (0..10).map(|i| req(i, 8, 4)).collect();
        let policy =
            BatchPolicy { max_active: 3, max_active_tokens: 1000, ..BatchPolicy::default() };
        let out = run_batch(&mut mock, reqs, &policy).unwrap();
        assert_eq!(out.len(), 10);
        assert_eq!(mock.prefill_order, (0..10).collect::<Vec<_>>());
        let mut ids: Vec<u64> = out.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn token_budget_limits_admission() {
        let mut mock = Mock::default();
        // Each request has 100 prompt tokens; budget 150 → one at a time
        // (the first always admits).
        let reqs: Vec<Request> = (0..3).map(|i| req(i, 98, 3)).collect();
        let policy =
            BatchPolicy { max_active: 8, max_active_tokens: 150, ..BatchPolicy::default() };
        let out = run_batch(&mut mock, reqs, &policy).unwrap();
        assert_eq!(out.len(), 3);
    }

    #[test]
    fn max_new_tokens_respected() {
        let mut mock = Mock::default();
        let out = run_batch(&mut mock, vec![req(7, 4, 2)], &BatchPolicy::default()).unwrap();
        assert!(out[0].tokens.len() <= 2);
    }

    #[test]
    fn one_prefill_interleaves_with_decode_rounds() {
        // ids ≡ 3 (mod 5) need 4 decode steps each, so all three stay
        // active while the later ones are admitted. The pre-fix batcher
        // burst-admitted every free slot before the first decode round
        // (ops would start Prefill, Prefill, Prefill).
        let mut mock = Mock::default();
        let reqs = vec![req(3, 4, 6), req(8, 4, 6), req(13, 4, 6)];
        let policy =
            BatchPolicy { max_active: 3, max_active_tokens: 1000, ..BatchPolicy::default() };
        run_batch(&mut mock, reqs, &policy).unwrap();
        let expected = [
            Op::Prefill { id: 3, needs_decode: true },
            Op::Round(1),
            Op::Prefill { id: 8, needs_decode: true },
            Op::Round(2),
            Op::Prefill { id: 13, needs_decode: true },
            Op::Round(3),
        ];
        assert_eq!(
            &mock.ops[..6],
            &expected[..],
            "prefills must interleave one-per-round with ongoing decodes"
        );
    }

    #[test]
    fn ttft_charged_from_request_arrival() {
        // Request 0 "arrived" 200ms ago; request 1 arrives now and must
        // not inherit that wait. The pre-fix batcher stamped one shared
        // t_admit at batch start, making both TTFTs near-zero.
        let mut mock = Mock::default();
        let now = Instant::now();
        let arrivals = vec![
            (req(0, 4, 2), now - Duration::from_millis(200)),
            (req(1, 4, 2), now),
        ];
        let policy =
            BatchPolicy { max_active: 1, max_active_tokens: 1000, ..BatchPolicy::default() };
        let out = run_batch_arrivals(&mut mock, arrivals, &policy).unwrap();
        let r0 = out.iter().find(|r| r.id == 0).unwrap();
        let r1 = out.iter().find(|r| r.id == 1).unwrap();
        assert!(
            r0.ttft >= 0.2,
            "TTFT must include the time since the request arrived, got {}",
            r0.ttft
        );
        assert!(
            r1.ttft < 0.15,
            "a fresh request must not inherit the oldest arrival's wait, got {}",
            r1.ttft
        );
    }

    #[test]
    fn policy_env_parsing() {
        assert_eq!(parse_env_usize(None).unwrap(), None);
        assert_eq!(parse_env_usize(Some("")).unwrap(), None);
        assert_eq!(parse_env_usize(Some(" 8 ")).unwrap(), Some(8));
        assert!(parse_env_usize(Some("zero")).is_err());
        assert!(parse_env_usize(Some("0")).is_err(), "zero slots would deadlock the loop");
    }

    #[test]
    fn policy_resolve_flag_beats_env() {
        let args = Args::parse_from(
            ["--max-active", "7", "--queue-depth", "2"].iter().map(|s| s.to_string()),
        );
        let p = BatchPolicy::resolve(&args);
        assert_eq!(p.max_active, 7);
        assert_eq!(p.queue_depth, 2);
        // Knob without a flag falls through to env/default; either way
        // it must be usable.
        assert!(p.max_active_tokens >= 1);
    }

    #[test]
    fn prop_batcher_invariants() {
        prop::check("batcher-invariants", 0xFEED, 150, |rng: &mut Rng| {
            let n = rng.range(1, 20);
            let reqs: Vec<Request> = (0..n as u64)
                .map(|i| req(i, rng.range(1, 50), rng.range(1, 8)))
                .collect();
            let policy = BatchPolicy {
                max_active: rng.range(1, 6),
                max_active_tokens: rng.range(60, 400),
                ..BatchPolicy::default()
            };
            let mut mock = Mock::default();
            let out = run_batch(&mut mock, reqs, &policy).unwrap();
            prop_assert_eq!(out.len(), n);
            // No request starved: every id appears exactly once.
            let mut ids: Vec<u64> = out.iter().map(|r| r.id).collect();
            ids.sort_unstable();
            prop_assert_eq!(ids, (0..n as u64).collect::<Vec<_>>());
            // FIFO prefill admission.
            prop_assert_eq!(mock.prefill_order, (0..n as u64).collect::<Vec<_>>());
            // Token limits respected.
            for r in &out {
                prop_assert!(r.tokens.len() <= 8, "too many tokens");
                prop_assert!(!r.tokens.is_empty(), "no first token");
            }
            // One prefill per round: while a session is mid-decode, two
            // prefills are never adjacent (a decode round separates
            // them). Adjacent prefills are fine when the first retired
            // at its prefill (needs_decode = false).
            for w in mock.ops.windows(2) {
                if let (Op::Prefill { needs_decode: true, .. }, Op::Prefill { .. }) =
                    (&w[0], &w[1])
                {
                    return Err(format!(
                        "adjacent prefills with a session in flight: {:?}",
                        mock.ops
                    ));
                }
            }
            // Every response's TTFT is charged from its own arrival —
            // with instant mock prefills it stays tiny but must never
            // be negative.
            for r in &out {
                prop_assert!(r.ttft >= 0.0, "negative ttft");
            }
            Ok(())
        });
    }
}
