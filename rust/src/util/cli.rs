//! Tiny CLI argument parser (clap replacement for the offline build).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.
//! Every binary/bench in this repo parses with [`Args`].

use std::collections::BTreeMap;

/// Parsed command-line arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// Positional arguments in order (the first one is usually a
    /// subcommand, e.g. `block-attn serve`).
    pub positional: Vec<String>,
    /// `--key value` / `--key=value` options; bare `--flag` maps to "true".
    pub options: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an explicit iterator (testable).
    pub fn parse_from<I: IntoIterator<Item = String>>(it: I) -> Args {
        let mut args = Args::default();
        let mut it = it.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    args.options.insert(rest.to_string(), v);
                } else {
                    args.options.insert(rest.to_string(), "true".to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    /// Parse the process arguments (skipping argv[0]).
    pub fn parse() -> Args {
        Args::parse_from(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.options.get(name).map(|v| v != "false").unwrap_or(false)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn u64_or(&self, name: &str, default: u64) -> u64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Comma-separated list of usizes, e.g. `--lengths 512,1024,2048`.
    pub fn usize_list_or(&self, name: &str, default: &[usize]) -> Vec<usize> {
        match self.get(name) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .filter_map(|s| s.trim().parse().ok())
                .collect(),
        }
    }

    /// `--threads N` (kernel thread budget). Returns `None` when absent
    /// or unparsable so the caller can fall through to the
    /// `BLOCK_ATTN_THREADS` env override and machine default (see
    /// `kernels::init_threads_from_args`).
    pub fn threads(&self) -> Option<usize> {
        self.get("threads").and_then(|v| v.parse().ok()).filter(|&n| n > 0)
    }

    /// `--kv-quant f32|int8|int4` (block-KV cache + decode-context
    /// storage precision).
    /// Returns the raw value; parsing/validation lives in
    /// `config::KvPrecision::resolve`, which also applies the
    /// `BLOCK_ATTN_KV_QUANT` env fallback.
    pub fn kv_quant(&self) -> Option<&str> {
        self.get("kv-quant")
    }

    /// `--reencode eager|delta` (block re-encode mode at fetch time).
    /// Returns the raw value; parsing/validation lives in
    /// `config::ReencodeMode::resolve`, which also applies the
    /// `BLOCK_ATTN_REENCODE` env fallback.
    pub fn reencode(&self) -> Option<&str> {
        self.get("reencode")
    }

    /// `--segment passages|text|icl|chat|gamecore|auto` (request
    /// segmentation policy of the serving front-end). Returns the raw
    /// value; parsing/validation lives in
    /// `config::SegmentPolicy::resolve`, which also applies the
    /// `BLOCK_ATTN_SEGMENT` env fallback.
    pub fn segment(&self) -> Option<&str> {
        self.get("segment")
    }

    /// `--simd auto|off` (vector-kernel dispatch mode). Returns the raw
    /// value; parsing/validation lives in `kernels::simd::SimdMode::resolve`,
    /// which also applies the `BLOCK_ATTN_SIMD` env fallback.
    pub fn simd(&self) -> Option<&str> {
        self.get("simd")
    }

    /// `--kv-store-dir PATH` (persistent block KV store directory).
    /// Raw value; resolution + the `BLOCK_ATTN_KV_STORE_DIR` env
    /// fallback live in `config::KvStoreConfig`.
    pub fn kv_store_dir(&self) -> Option<&str> {
        self.get("kv-store-dir")
    }

    /// `--kv-store-budget MB` (disk budget of the store, 0 =
    /// unbounded). Raw value; parsed in `config::KvStoreConfig`, which
    /// also applies the `BLOCK_ATTN_KV_STORE_BUDGET` env fallback.
    pub fn kv_store_budget(&self) -> Option<&str> {
        self.get("kv-store-budget")
    }

    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(|s| s.to_string()))
    }

    #[test]
    fn mixed_forms() {
        let a = parse("serve --port 8080 --verbose --model=tiny extra");
        assert_eq!(a.subcommand(), Some("serve"));
        assert_eq!(a.usize_or("port", 0), 8080);
        assert!(a.flag("verbose"));
        assert_eq!(a.get("model"), Some("tiny"));
        assert_eq!(a.positional, vec!["serve", "extra"]);
    }

    #[test]
    fn defaults() {
        let a = parse("run");
        assert_eq!(a.usize_or("n", 7), 7);
        assert_eq!(a.str_or("x", "d"), "d");
        assert!(!a.flag("missing"));
    }

    #[test]
    fn lists() {
        let a = parse("--lengths 512,1024, 2048");
        assert_eq!(a.usize_list_or("lengths", &[]), vec![512, 1024]);
        let b = parse("--lengths=1,2,3");
        assert_eq!(b.usize_list_or("lengths", &[]), vec![1, 2, 3]);
        let c = parse("x");
        assert_eq!(c.usize_list_or("lengths", &[9]), vec![9]);
    }

    #[test]
    fn threads_accessor() {
        assert_eq!(parse("--threads 6").threads(), Some(6));
        assert_eq!(parse("--threads=0").threads(), None);
        assert_eq!(parse("--threads nope").threads(), None);
        assert_eq!(parse("run").threads(), None);
    }

    #[test]
    fn kv_quant_accessor() {
        assert_eq!(parse("--kv-quant int8").kv_quant(), Some("int8"));
        assert_eq!(parse("--kv-quant=f32").kv_quant(), Some("f32"));
        assert_eq!(parse("run").kv_quant(), None);
    }

    #[test]
    fn reencode_accessor() {
        assert_eq!(parse("--reencode delta").reencode(), Some("delta"));
        assert_eq!(parse("--reencode=eager").reencode(), Some("eager"));
        assert_eq!(parse("run").reencode(), None);
    }

    #[test]
    fn segment_accessor() {
        assert_eq!(parse("--segment text").segment(), Some("text"));
        assert_eq!(parse("--segment=auto").segment(), Some("auto"));
        assert_eq!(parse("run").segment(), None);
    }

    #[test]
    fn simd_accessor() {
        assert_eq!(parse("--simd off").simd(), Some("off"));
        assert_eq!(parse("--simd=auto").simd(), Some("auto"));
        assert_eq!(parse("run").simd(), None);
    }

    #[test]
    fn kv_store_accessors() {
        assert_eq!(parse("--kv-store-dir /tmp/kv").kv_store_dir(), Some("/tmp/kv"));
        assert_eq!(parse("--kv-store-dir=/tmp/kv").kv_store_dir(), Some("/tmp/kv"));
        assert_eq!(parse("run").kv_store_dir(), None);
        assert_eq!(parse("--kv-store-budget 64").kv_store_budget(), Some("64"));
        assert_eq!(parse("run").kv_store_budget(), None);
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse("--a --b v");
        assert!(a.flag("a"));
        assert_eq!(a.get("b"), Some("v"));
    }
}
